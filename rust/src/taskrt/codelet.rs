//! Codelets: the StarPU-analog unit of multi-variant computation.
//!
//! A codelet corresponds 1:1 to a COMPAR *interface* (paper §2.2): one
//! logical function (e.g. `mmul`) with several *implementation variants*
//! (`mmul_omp`, `mmul_cuda`, ...), each targeting an architecture. The
//! generated glue (compar/codegen) builds these at startup; applications
//! can also build them by hand through this API (the "raw StarPU"
//! programmability baseline of Table 1f).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::data::AccessMode;
use super::device::Arch;
use crate::runtime::Tensor;

/// Buffer view passed to native implementations: the tensors of the
/// task's handles, in declaration order (paper `parameter` order).
pub struct ExecBuffers {
    pub tensors: Vec<Arc<Mutex<Tensor>>>,
    pub modes: Vec<AccessMode>,
    /// The task's scale parameter (paper `size` clause).
    pub size: usize,
}

impl ExecBuffers {
    /// Lock buffer `i` for reading (panics on out-of-range).
    pub fn read(&self, i: usize) -> std::sync::MutexGuard<'_, Tensor> {
        assert!(self.modes[i].reads(), "buffer {i} is not readable");
        self.tensors[i].lock().unwrap()
    }

    /// Lock buffer `i` for writing.
    pub fn write(&self, i: usize) -> std::sync::MutexGuard<'_, Tensor> {
        assert!(self.modes[i].writes(), "buffer {i} is not writable");
        self.tensors[i].lock().unwrap()
    }
}

/// Native (CPU) implementation body.
pub type NativeFn = Arc<dyn Fn(&ExecBuffers) -> Result<()> + Send + Sync>;

/// How an implementation variant executes.
#[derive(Clone)]
pub enum ImplKind {
    /// Rust function run directly on the worker thread (the paper's
    /// Seq / OpenMP variants).
    Native(NativeFn),
    /// AOT-compiled HLO artifact executed through the XLA service (the
    /// paper's CUDA / CUBLAS / BLAS-library variants). `variant` selects
    /// the artifact family in the manifest (e.g. "jnp", "pallas").
    Artifact { artifact_variant: String },
}

impl std::fmt::Debug for ImplKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ImplKind::Native(_) => write!(f, "Native"),
            ImplKind::Artifact { artifact_variant } => {
                write!(f, "Artifact({artifact_variant})")
            }
        }
    }
}

/// One implementation variant of a codelet.
#[derive(Clone, Debug)]
pub struct Implementation {
    /// Paper-facing variant name ("omp", "cuda", "cublas", ...). Used by
    /// the device model and in every report.
    pub name: String,
    pub arch: Arch,
    pub kind: ImplKind,
}

/// A multi-variant computation bound to a parameter signature.
#[derive(Clone, Debug)]
pub struct Codelet {
    /// Interface name (paper `interface` clause), e.g. "mmul".
    pub name: String,
    /// App key for the device model / manifest ("matmul", "hotspot", ...).
    pub app: String,
    /// Parameter access modes, in declaration order.
    pub modes: Vec<AccessMode>,
    pub impls: Vec<Implementation>,
    /// Component-author selection hint: the variant name expected to win
    /// (the pre-compiler's `prefer(...)` clause lands here). Selection
    /// policies explore the hinted variant first while models are cold.
    pub hint: Option<String>,
}

impl Codelet {
    pub fn new(name: &str, app: &str, modes: Vec<AccessMode>) -> Codelet {
        Codelet {
            name: name.to_string(),
            app: app.to_string(),
            modes,
            impls: Vec::new(),
            hint: None,
        }
    }

    /// Seed selection priors with the expected-winner variant (builder
    /// style; emitted by the pre-compiler's `prefer(...)` clause).
    pub fn with_hint(mut self, variant: &str) -> Codelet {
        self.hint = Some(variant.to_string());
        self
    }

    /// Add a native variant (builder style).
    pub fn with_native(mut self, variant: &str, arch: Arch, f: NativeFn) -> Codelet {
        self.impls.push(Implementation {
            name: variant.to_string(),
            arch,
            kind: ImplKind::Native(f),
        });
        self
    }

    /// Add an artifact-backed variant.
    pub fn with_artifact(mut self, variant: &str, arch: Arch, artifact_variant: &str) -> Codelet {
        self.impls.push(Implementation {
            name: variant.to_string(),
            arch,
            kind: ImplKind::Artifact {
                artifact_variant: artifact_variant.to_string(),
            },
        });
        self
    }

    /// Variants runnable on `arch`.
    pub fn impls_for(&self, arch: Arch) -> impl Iterator<Item = (usize, &Implementation)> {
        self.impls
            .iter()
            .enumerate()
            .filter(move |(_, i)| i.arch == arch)
    }

    pub fn can_run_on(&self, arch: Arch) -> bool {
        self.impls.iter().any(|i| i.arch == arch)
    }

    pub fn impl_by_name(&self, name: &str) -> Option<(usize, &Implementation)> {
        self.impls
            .iter()
            .enumerate()
            .find(|(_, i)| i.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Codelet {
        Codelet::new("mmul", "matmul", vec![
            AccessMode::Read,
            AccessMode::Read,
            AccessMode::Write,
        ])
        .with_native("omp", Arch::Cpu, Arc::new(|_| Ok(())))
        .with_artifact("cuda", Arch::Cuda, "jnp")
        .with_artifact("cublas", Arch::Cuda, "pallas")
    }

    #[test]
    fn arch_filtering() {
        let c = sample();
        assert_eq!(c.impls_for(Arch::Cpu).count(), 1);
        assert_eq!(c.impls_for(Arch::Cuda).count(), 2);
        assert!(c.can_run_on(Arch::Cuda));
    }

    #[test]
    fn lookup_by_name() {
        let c = sample();
        let (idx, imp) = c.impl_by_name("cublas").unwrap();
        assert_eq!(idx, 2);
        assert_eq!(imp.arch, Arch::Cuda);
        assert!(c.impl_by_name("opencl").is_none());
    }

    #[test]
    fn buffers_respect_modes() {
        let bufs = ExecBuffers {
            tensors: vec![Arc::new(Mutex::new(Tensor::vector(vec![1.0])))],
            modes: vec![AccessMode::Read],
            size: 1,
        };
        assert_eq!(bufs.read(0).data()[0], 1.0);
    }

    #[test]
    #[should_panic(expected = "not writable")]
    fn write_readonly_panics() {
        let bufs = ExecBuffers {
            tensors: vec![Arc::new(Mutex::new(Tensor::vector(vec![1.0])))],
            modes: vec![AccessMode::Read],
            size: 1,
        };
        drop(bufs.write(0));
    }
}
