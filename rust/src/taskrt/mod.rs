//! `taskrt` — the StarPU-analog heterogeneous task runtime (DESIGN.md S5).
//!
//! Applications (or the COMPAR-generated glue) register data handles and
//! multi-variant codelets, then submit tasks; the runtime resolves
//! implicit data dependencies, lets the configured scheduler choose an
//! implementation variant + worker, simulates the heterogeneous device
//! timing (DESIGN.md §3) while executing every task for real (native
//! Rust or an AOT XLA artifact), and feeds observed times back into the
//! history-based performance models that drive future selections.

pub mod codelet;
pub mod config;
pub mod data;
pub mod device;
pub mod hwloc;
pub mod metrics;
pub mod perfmodel;
pub mod scheduler;
pub mod task;
pub mod trace;
mod worker;

pub use codelet::{Codelet, ExecBuffers, ImplKind, Implementation, NativeFn};
pub use config::{Config, SchedPolicy, TimeMode};
pub use data::{AccessMode, DataRegistry, HandleId, MAIN_MEMORY};
pub use device::Arch;
pub use metrics::{Metrics, TaskResult};
pub use perfmodel::PerfModels;
pub use task::{TaskId, TaskSpec, TaskState};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{Manifest, Tensor, XlaHandle, XlaService};
use scheduler::{ReadyTask, SchedCtx, Scheduler, WorkerInfo};
use task::TaskTable;

/// Shared runtime state (one per [`Runtime`]).
pub(crate) struct Inner {
    pub config: Config,
    pub data: Arc<DataRegistry>,
    pub codelets: RwLock<HashMap<String, Arc<Codelet>>>,
    pub tasks: Mutex<TaskTable>,
    pub sched: Box<dyn Scheduler>,
    pub ctx: SchedCtx,
    pub perf: Arc<PerfModels>,
    pub metrics: Metrics,
    pub noise: device::NoiseSource,
    pub manifest: Option<Arc<Manifest>>,
    pub xla: Option<XlaHandle>,
    pub shutdown: AtomicBool,
    /// (in-flight count, condvar) for wait_all.
    pub inflight: Mutex<usize>,
    pub inflight_cv: Condvar,
    /// Runtime start time; task trace timestamps are relative to this.
    pub epoch: std::time::Instant,
}

/// The COMPAR runtime: StarPU's `starpu_init` .. `starpu_shutdown`
/// lifecycle. Created by generated glue (`compar_init()`) or directly.
pub struct Runtime {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
    /// Keep the XLA service alive for the runtime's lifetime.
    _xla_service: Option<XlaService>,
}

impl Runtime {
    /// Bring up workers (and the XLA engine thread if any CUDA-analog
    /// devices or artifact variants are configured).
    pub fn new(config: Config, manifest: Option<Arc<Manifest>>) -> Result<Runtime> {
        if config.total_workers() == 0 {
            bail!("configuration has zero workers (ncpu=0 and ncuda=0)");
        }
        // Build the worker list from the device topology.
        let mut infos = Vec::new();
        for dev in device::paper_topology(config.ncpu, config.ncuda) {
            for _ in 0..dev.workers {
                infos.push(WorkerInfo {
                    id: infos.len(),
                    arch: dev.arch,
                    mem_node: dev.mem_node,
                });
            }
        }

        // The XLA service thread is needed whenever artifacts may run.
        let xla_service = if manifest.is_some() {
            Some(XlaService::spawn()?)
        } else {
            None
        };
        let xla = xla_service.as_ref().map(|s| s.handle());

        let data = Arc::new(DataRegistry::new());
        let perf = Arc::new(PerfModels::new());
        if let Some(dir) = &config.perfmodel_dir {
            let path = dir.join("models.json");
            if path.exists() {
                perf.load(&path)?;
            }
        }
        let mut ctx = SchedCtx::new(
            infos.clone(),
            perf.clone(),
            data.clone(),
            manifest.clone(),
            config.calibrate,
            config.seed,
        );
        ctx.data_aware = config.data_aware;
        let sched = scheduler::make(config.sched);
        let noise = device::NoiseSource::new(config.seed ^ 0x5eed, 0.05);

        let inner = Arc::new(Inner {
            config,
            data,
            codelets: RwLock::new(HashMap::new()),
            tasks: Mutex::new(TaskTable::new()),
            sched,
            ctx,
            perf,
            metrics: Metrics::new(),
            noise,
            manifest,
            xla,
            shutdown: AtomicBool::new(false),
            inflight: Mutex::new(0),
            inflight_cv: Condvar::new(),
            epoch: std::time::Instant::now(),
        });

        let workers = infos
            .iter()
            .map(|info| {
                let inner = inner.clone();
                let info = info.clone();
                std::thread::Builder::new()
                    .name(format!("worker-{}-{}", info.arch.name(), info.id))
                    .spawn(move || worker::run(inner, info))
                    .expect("spawning worker")
            })
            .collect();

        Ok(Runtime {
            inner,
            workers,
            _xla_service: xla_service,
        })
    }

    /// Convenience: default config from env + artifacts from the default
    /// directory if present.
    pub fn from_env() -> Result<Runtime> {
        let dir = crate::runtime::manifest::default_dir();
        let manifest = if dir.join("manifest.json").exists() {
            Some(Arc::new(Manifest::load(&dir)?))
        } else {
            None
        };
        Runtime::new(Config::from_env(), manifest)
    }

    pub fn config(&self) -> &Config {
        &self.inner.config
    }

    pub fn manifest(&self) -> Option<&Arc<Manifest>> {
        self.inner.manifest.as_ref()
    }

    // ------------------------------------------------------------- data

    pub fn register_data(&self, t: Tensor) -> HandleId {
        self.inner.data.register(t)
    }

    pub fn register_data_named(&self, name: &str, t: Tensor) -> HandleId {
        self.inner.data.register_named(name, t)
    }

    /// Copy out a handle's current contents (implies wait_all first for
    /// deterministic reads in app code; we do not wait here).
    pub fn snapshot(&self, id: HandleId) -> Result<Tensor> {
        self.inner.data.snapshot(id)
    }

    pub fn data(&self) -> &Arc<DataRegistry> {
        &self.inner.data
    }

    // --------------------------------------------------------- codelets

    pub fn register_codelet(&self, c: Codelet) -> Arc<Codelet> {
        let arc = Arc::new(c);
        self.inner
            .codelets
            .write()
            .unwrap()
            .insert(arc.name.clone(), arc.clone());
        arc
    }

    pub fn codelet(&self, name: &str) -> Option<Arc<Codelet>> {
        self.inner.codelets.read().unwrap().get(name).cloned()
    }

    // ------------------------------------------------------------ tasks

    /// Submit a task. Implicit dependencies (sequential consistency over
    /// its data handles) are resolved here; the task enters the scheduler
    /// as soon as they clear.
    pub fn submit(&self, spec: TaskSpec) -> Result<TaskId> {
        // validate executability up front (StarPU would hang instead)
        let archs: Vec<Arch> = self
            .inner
            .ctx
            .workers
            .iter()
            .map(|w| w.arch)
            .collect();
        let probe = ReadyTask {
            id: 0,
            codelet: spec.codelet.clone(),
            size: spec.size,
            handles: spec.handles.clone(),
            force_variant: spec.force_variant.clone(),
            priority: spec.priority,
            chosen_impl: None,
            est_cost_ns: 0,
        };
        if !archs
            .iter()
            .any(|&a| !self.inner.ctx.eligible_impls(&probe, a).is_empty())
        {
            bail!(
                "task on codelet '{}' (size {}) has no eligible implementation \
                 for the current topology (ncpu={}, ncuda={}, forced={:?})",
                spec.codelet.name,
                spec.size,
                self.inner.config.ncpu,
                self.inner.config.ncuda,
                spec.force_variant
            );
        }

        *self.inner.inflight.lock().unwrap() += 1;

        let (id, ready) = {
            let mut table = self.inner.tasks.lock().unwrap();
            // record_access needs the task id before insertion; TaskTable
            // assigns ids sequentially, so use the announced next id.
            let next = table.next_id();
            let mut deps = Vec::new();
            for (h, m) in &spec.handles {
                deps.extend(self.inner.data.record_access(*h, next as usize, *m)?);
            }
            let mut deps: Vec<TaskId> = deps.into_iter().map(|d| d as TaskId).collect();
            // explicit dependencies (starpu_task_declare_deps analog)
            deps.extend(spec.after.iter().copied());
            deps.sort_unstable();
            deps.dedup();
            let (id, ready) = table.insert(spec, &deps);
            debug_assert_eq!(id, next, "task id drift");
            (id, ready)
        };

        if ready {
            worker::push_ready(&self.inner, id);
        }
        Ok(id)
    }

    /// Block until every submitted task has finished. Returns the first
    /// execution error, if any task failed.
    pub fn wait_all(&self) -> Result<()> {
        let mut inflight = self.inner.inflight.lock().unwrap();
        while *inflight > 0 {
            inflight = self.inner.inflight_cv.wait(inflight).unwrap();
        }
        drop(inflight);
        let table = self.inner.tasks.lock().unwrap();
        if let Some(e) = table.first_error() {
            return Err(anyhow!("task failed: {e}"));
        }
        Ok(())
    }

    pub fn task_state(&self, id: TaskId) -> Option<TaskState> {
        self.inner.tasks.lock().unwrap().state(id)
    }

    // ---------------------------------------------------------- metrics

    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    pub fn drain_results(&self) -> Vec<TaskResult> {
        self.inner.metrics.drain_results()
    }

    pub fn perf_models(&self) -> &Arc<PerfModels> {
        &self.inner.perf
    }

    /// Export the execution trace (chrome://tracing JSON) of everything
    /// recorded so far — StarPU's FxT trace analog.
    pub fn export_chrome_trace(&self, path: &std::path::Path) -> Result<()> {
        trace::export_chrome_trace(&self.inner.metrics.results(), &self.inner.ctx.workers, path)
    }

    /// Persist perf models to the configured directory.
    pub fn save_perf_models(&self) -> Result<()> {
        if let Some(dir) = &self.inner.config.perfmodel_dir {
            self.inner.perf.save(&dir.join("models.json"))?;
        }
        Ok(())
    }

    /// Graceful shutdown: waits for queues to drain, then joins workers.
    pub fn shutdown(mut self) -> Result<()> {
        self.wait_all()?;
        self.inner.shutdown.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.save_perf_models()
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}
