//! `taskrt` — the StarPU-analog heterogeneous task runtime (DESIGN.md S5).
//!
//! Applications (or the COMPAR-generated glue) register data handles and
//! multi-variant codelets, then submit tasks; the runtime resolves
//! implicit data dependencies, lets the configured scheduler choose an
//! implementation variant + worker, simulates the heterogeneous device
//! timing (DESIGN.md §3) while executing every task for real (native
//! Rust or an AOT XLA artifact), and feeds observed times back into the
//! history-based performance models that drive future selections.
//!
//! ## Scheduling contexts
//!
//! Since the multi-tenant serving work, a single [`Runtime`] can be
//! partitioned into named **scheduling contexts** (StarPU's
//! `sched_ctx` analog): each context owns a worker subset and its own
//! scheduler policy + queues, while every context shares one
//! [`DataRegistry`], one [`PerfModels`] store and one XLA service.
//! Tasks submitted under a context ([`TaskSpec::in_context`]) are
//! scheduled strictly within its partition. [`Runtime::create_context`]
//! carves workers out of their current contexts; context 0 is the
//! default context and initially owns every worker.
//!
//! ## Variant selection
//!
//! *Which implementation variant* runs is decided by the pluggable
//! [`selection`] subsystem: every scheduling context carries a
//! [`SelectionPolicy`] instance (choose one per context via
//! [`Runtime::create_context_with`]), tasks may override it per-task
//! ([`TaskSpec::with_selector`] / [`TaskSpec::with_variant`]), and
//! workers feed measured execution times back through
//! [`SelectionPolicy::feedback`] — the online-learning loop. Every
//! policy consultation carries a first-class [`SelectionQuery`]: the
//! (task, arch) pair plus a [`RuntimeSnapshot`] of queue depth, worker
//! occupancy, operand residency and co-tenancy, so context-aware
//! policies (the `contextual` selector) can condition on runtime state,
//! not just problem shape.

pub mod codelet;
pub mod config;
pub mod data;
pub mod device;
pub mod hwloc;
pub mod metrics;
pub mod perfmodel;
pub mod scheduler;
pub mod selection;
pub mod task;
pub mod trace;
mod worker;

pub use codelet::{Codelet, ExecBuffers, ImplKind, Implementation, NativeFn};
pub use config::{Config, SchedPolicy, TimeMode};
pub use data::{AccessMode, DataRegistry, HandleId, MAIN_MEMORY};
pub use device::Arch;
pub use metrics::{Metrics, TaskResult};
pub use perfmodel::PerfModels;
pub use selection::{
    validate_occupancy, RuntimeSnapshot, SelectReason, SelectionPolicy, SelectionQuery,
    SelectorKind, VariantChoice, WorkerOccupancy, VALID_SELECTORS,
};
pub use task::{TaskId, TaskSpec, TaskState};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::plan::{Candidate, GraphPlanner, GraphRun, GraphSpec, PlanMode, PlanNode, PlannerInput};
use crate::runtime::{Manifest, Tensor, XlaHandle, XlaService};
use crate::util::json::Json;
use scheduler::{ReadyTask, SchedCtx, Scheduler, WorkerInfo};
use selection::Planned;
use task::TaskTable;

/// Scheduling-context id: index into the runtime's context table.
pub type CtxId = usize;

/// The default context; owns every worker until others are carved out.
pub const DEFAULT_CTX: CtxId = 0;

/// One scheduling context: a worker partition with its own policy and
/// queues. Immutable once published; reconfiguration replaces the slot.
pub(crate) struct ContextSlot {
    pub name: String,
    pub policy: SchedPolicy,
    /// Kind of the variant-selection policy (the live instance lives in
    /// `ctx.selector`); kept so slot rebuilds preserve the choice.
    pub selector: SelectorKind,
    pub sched: Box<dyn Scheduler>,
    pub ctx: SchedCtx,
}

/// Public descriptor of one scheduling context (diagnostics / serving).
#[derive(Debug, Clone)]
pub struct ContextInfo {
    pub id: CtxId,
    pub name: String,
    pub policy: SchedPolicy,
    /// Variant-selection policy name (e.g. "greedy", "epsilon:0.1").
    pub selector: String,
    /// Global worker ids in this context's partition.
    pub workers: Vec<usize>,
    /// Tasks currently queued in this context's scheduler.
    pub queued: usize,
}

/// Per-context load sample — the input of elastic control loops
/// ([`crate::autoscale`]). Mirrors the [`RuntimeSnapshot`] features at
/// context granularity.
#[derive(Debug, Clone)]
pub struct CtxLoad {
    pub id: CtxId,
    pub name: String,
    /// Member workers in the partition.
    pub workers: usize,
    /// Tasks pushed to this context's scheduler, not yet popped.
    pub queue_depth: usize,
    /// Member workers currently executing a task.
    pub busy: usize,
    /// Modeled backlog seconds on the least-loaded member — the
    /// best-case wait a newly placed task would see.
    pub queued_secs: f64,
    /// Live serve-layer sessions sharing the runtime.
    pub tenants: usize,
}

/// One context's membership and occupancy in an [`AuditedState`].
#[derive(Debug, Clone)]
pub struct CtxAudit {
    pub id: CtxId,
    pub name: String,
    /// Sorted global worker ids of the partition.
    pub members: Vec<usize>,
    /// `(worker, arch, in-flight count)` per member — the exact tuples
    /// [`validate_occupancy`] was run over.
    pub occupancy: Vec<WorkerOccupancy>,
    /// Tasks queued in this context's scheduler (clamped at 0).
    pub queue_depth: usize,
}

/// A validated structural snapshot of the runtime's concurrency core,
/// captured under the reconfiguration lock so membership is stable for
/// the duration of the read. This is the observable the pure model in
/// [`crate::model`] diffs against: if capture fails, the live counters
/// violated the audited invariants.
#[derive(Debug, Clone)]
pub struct AuditedState {
    pub contexts: Vec<CtxAudit>,
    /// Total workers in the topology (every context indexes into it).
    pub total_workers: usize,
}

/// Shared runtime state (one per [`Runtime`]).
pub(crate) struct Inner {
    pub config: Config,
    pub data: Arc<DataRegistry>,
    pub codelets: RwLock<HashMap<String, Arc<Codelet>>>,
    pub tasks: Mutex<TaskTable>,
    /// Notified on every task completion (for [`Runtime::wait_tasks`]).
    pub tasks_cv: Condvar,
    /// Full machine topology (all contexts index into this).
    pub workers: Vec<WorkerInfo>,
    /// Context table; slots are only appended or replaced, never removed,
    /// so a `CtxId` stays valid for the runtime's lifetime.
    pub contexts: RwLock<Vec<Arc<ContextSlot>>>,
    /// Current context of each worker (indexed by global worker id).
    pub worker_ctx: Vec<AtomicUsize>,
    pub perf: Arc<PerfModels>,
    /// Live serve-layer sessions sharing this runtime (the co-tenant
    /// count selection snapshots report); shared into every context's
    /// `SchedCtx` so policies can observe it.
    pub tenants: Arc<AtomicUsize>,
    pub metrics: Metrics,
    pub noise: device::NoiseSource,
    pub manifest: Option<Arc<Manifest>>,
    pub xla: Option<XlaHandle>,
    pub shutdown: AtomicBool,
    /// Serializes live reconfigurations ([`Runtime::move_workers`]):
    /// two concurrent migrations must not pick the same worker.
    pub reconfig: Mutex<()>,
    /// (in-flight count, condvar) for wait_all.
    pub inflight: Mutex<usize>,
    pub inflight_cv: Condvar,
    /// Runtime start time; task trace timestamps are relative to this.
    /// Copied from `obs.epoch()` so worker task spans and serve-layer
    /// request spans share one timeline.
    pub epoch: std::time::Instant,
    /// Live observability plane (metrics registry, decision audit,
    /// trace ring) shared by every context's `SchedCtx`.
    pub obs: Arc<crate::obs::Obs>,
}

impl Inner {
    /// Fetch a context slot by id.
    pub(crate) fn slot(&self, id: CtxId) -> Option<Arc<ContextSlot>> {
        self.contexts.read().unwrap().get(id).cloned()
    }

    fn make_slot(
        &self,
        name: &str,
        policy: SchedPolicy,
        selector: SelectorKind,
        members: Vec<usize>,
        salt: u64,
    ) -> ContextSlot {
        let mut ctx = SchedCtx::new(
            self.workers.clone(),
            self.perf.clone(),
            self.data.clone(),
            self.manifest.clone(),
            selector.build(self.config.seed ^ salt),
            self.config.seed ^ salt,
        );
        ctx.data_aware = self.config.data_aware;
        ctx.tenants = self.tenants.clone();
        ctx.obs = self.obs.clone();
        ctx.set_members(members);
        ContextSlot {
            name: name.to_string(),
            policy,
            selector,
            sched: scheduler::make(policy),
            ctx,
        }
    }
}

/// The COMPAR runtime: StarPU's `starpu_init` .. `starpu_shutdown`
/// lifecycle. Created by generated glue (`compar_init()`) or directly.
pub struct Runtime {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
    /// Keep the XLA service alive for the runtime's lifetime.
    _xla_service: Option<XlaService>,
}

impl Runtime {
    /// Bring up workers (and the XLA engine thread if any CUDA-analog
    /// devices or artifact variants are configured).
    pub fn new(config: Config, manifest: Option<Arc<Manifest>>) -> Result<Runtime> {
        if config.total_workers() == 0 {
            bail!("configuration has zero workers (ncpu=0 and ncuda=0)");
        }
        // Build the worker list from the device topology.
        let mut infos = Vec::new();
        for dev in device::paper_topology(config.ncpu, config.ncuda) {
            for _ in 0..dev.workers {
                infos.push(WorkerInfo {
                    id: infos.len(),
                    arch: dev.arch,
                    mem_node: dev.mem_node,
                });
            }
        }

        // The XLA service thread is needed whenever artifacts may run.
        // When unavailable (e.g. built without the `xla` feature), degrade
        // to native-only execution: without a manifest the artifact
        // variants are simply ineligible.
        let mut manifest = manifest;
        let xla_service = if manifest.is_some() {
            match XlaService::spawn() {
                Ok(s) => Some(s),
                Err(e) => {
                    eprintln!(
                        "warning: XLA unavailable ({e:#}); \
                         artifact variants disabled, running native-only"
                    );
                    manifest = None;
                    None
                }
            }
        } else {
            None
        };
        let xla = xla_service.as_ref().map(|s| s.handle());

        let data = Arc::new(DataRegistry::new());
        let perf = Arc::new(PerfModels::new());
        if let Some(dir) = &config.perfmodel_dir {
            let path = dir.join("models.json");
            if path.exists() {
                perf.load(&path)?;
            }
        }
        let worker_ctx = (0..infos.len())
            .map(|_| AtomicUsize::new(DEFAULT_CTX))
            .collect();
        let noise = device::NoiseSource::new(config.seed ^ 0x5eed, 0.05);
        // One observability plane per runtime; its construction instant
        // is the shared epoch for worker and serve-layer trace spans.
        let obs = Arc::new(crate::obs::Obs::new());
        let epoch = obs.epoch();

        let inner = Arc::new(Inner {
            config,
            data,
            codelets: RwLock::new(HashMap::new()),
            tasks: Mutex::new(TaskTable::new()),
            tasks_cv: Condvar::new(),
            workers: infos.clone(),
            contexts: RwLock::new(Vec::new()),
            worker_ctx,
            perf,
            tenants: Arc::new(AtomicUsize::new(0)),
            metrics: Metrics::new(),
            noise,
            manifest,
            xla,
            shutdown: AtomicBool::new(false),
            reconfig: Mutex::new(()),
            inflight: Mutex::new(0),
            inflight_cv: Condvar::new(),
            epoch,
            obs,
        });
        // default context 0: all workers, the configured policies
        {
            let members: Vec<usize> = (0..inner.workers.len()).collect();
            let selector = inner.config.effective_selector();
            let slot = inner.make_slot("default", inner.config.sched, selector, members, 0);
            inner.contexts.write().unwrap().push(Arc::new(slot));
        }

        let workers = infos
            .iter()
            .map(|info| {
                let inner = inner.clone();
                let info = info.clone();
                std::thread::Builder::new()
                    .name(format!("worker-{}-{}", info.arch.name(), info.id))
                    .spawn(move || worker::run(inner, info))
                    .expect("spawning worker")
            })
            .collect();

        Ok(Runtime {
            inner,
            workers,
            _xla_service: xla_service,
        })
    }

    /// Convenience: default config from env + artifacts from the default
    /// directory if present.
    pub fn from_env() -> Result<Runtime> {
        let dir = crate::runtime::manifest::default_dir();
        let manifest = if dir.join("manifest.json").exists() {
            Some(Arc::new(Manifest::load(&dir)?))
        } else {
            None
        };
        Runtime::new(Config::from_env(), manifest)
    }

    pub fn config(&self) -> &Config {
        &self.inner.config
    }

    pub fn manifest(&self) -> Option<&Arc<Manifest>> {
        self.inner.manifest.as_ref()
    }

    // -------------------------------------------------------- contexts

    /// Carve a new scheduling context with the runtime's default
    /// variant-selection policy ([`Config::effective_selector`]).
    pub fn create_context(
        &self,
        name: &str,
        workers: &[usize],
        policy: SchedPolicy,
    ) -> Result<CtxId> {
        self.create_context_with(name, workers, policy, self.inner.config.effective_selector())
    }

    /// Carve a new scheduling context out of the runtime: `workers` move
    /// from their current contexts into a fresh partition running
    /// scheduler `policy` and variant-selection policy `selector` (so
    /// different tenants can run different selection strategies over one
    /// machine). Requires a quiescent runtime (no tasks in flight) so no
    /// queued task can strand on a reassigned worker; concurrent submits
    /// block until the reconfiguration completes.
    pub fn create_context_with(
        &self,
        name: &str,
        workers: &[usize],
        policy: SchedPolicy,
        selector: SelectorKind,
    ) -> Result<CtxId> {
        let mut members: Vec<usize> = workers.to_vec();
        members.sort_unstable();
        members.dedup();
        if members.is_empty() {
            bail!("context '{name}' needs at least one worker");
        }
        if let Some(&bad) = members.iter().find(|&&w| w >= self.inner.workers.len()) {
            bail!(
                "context '{name}': worker {bad} out of range (topology has {})",
                self.inner.workers.len()
            );
        }
        // Serialize against live worker migrations (move_workers), then
        // hold the inflight lock for the whole reconfiguration:
        // quiescence can't be invalidated by a concurrent submit.
        let _reconfig = self.inner.reconfig.lock().unwrap();
        let inflight = self.inner.inflight.lock().unwrap();
        if *inflight > 0 {
            bail!(
                "create_context('{name}') requires a quiescent runtime \
                 ({} task(s) in flight — call wait_all first)",
                *inflight
            );
        }
        let mut contexts = self.inner.contexts.write().unwrap();
        if contexts.iter().any(|c| c.name == name) {
            bail!("context '{name}' already exists");
        }
        let id = contexts.len();

        // Shrink every context losing workers. Membership is interior-
        // mutable (the autoscale work), so donors update in place: their
        // scheduler queues (empty — the runtime is quiescent) and
        // learned selection-policy state survive the repartition.
        let mut donors: Vec<CtxId> = members
            .iter()
            .map(|&w| self.inner.worker_ctx[w].load(Ordering::Acquire))
            .collect();
        donors.sort_unstable();
        donors.dedup();
        for donor in donors {
            let old = &contexts[donor];
            let keep: Vec<usize> = old
                .ctx
                .members()
                .into_iter()
                .filter(|w| !members.contains(w))
                .collect();
            old.ctx.set_members(keep);
        }

        let slot =
            self.inner
                .make_slot(name, policy, selector, members.clone(), 0x9e3779b9 ^ id as u64);
        contexts.push(Arc::new(slot));
        for &w in &members {
            self.inner.worker_ctx[w].store(id, Ordering::Release);
        }
        drop(contexts);
        drop(inflight);
        Ok(id)
    }

    /// Look up a context id by name ("default" is context 0).
    pub fn context_id(&self, name: &str) -> Option<CtxId> {
        self.inner
            .contexts
            .read()
            .unwrap()
            .iter()
            .position(|c| c.name == name)
    }

    /// Describe every scheduling context (partition + queue depth).
    pub fn contexts(&self) -> Vec<ContextInfo> {
        let contexts = self.inner.contexts.read().unwrap();
        contexts
            .iter()
            .enumerate()
            .map(|(id, c)| ContextInfo {
                id,
                name: c.name.clone(),
                policy: c.policy,
                selector: c.selector.name(),
                workers: c.ctx.members(),
                queued: c.sched.queued(),
            })
            .collect()
    }

    /// Name of a context's variant-selection policy (serve layer).
    pub fn context_selector_name(&self, id: CtxId) -> Option<String> {
        self.inner
            .contexts
            .read()
            .unwrap()
            .get(id)
            .map(|c| c.selector.name())
    }

    /// Member workers currently in context `id` (0 for an unknown id).
    pub fn worker_count_in(&self, id: CtxId) -> usize {
        self.inner
            .contexts
            .read()
            .unwrap()
            .get(id)
            .map(|c| c.ctx.member_count())
            .unwrap_or(0)
    }

    /// Per-context load samples — the elastic control loop's input.
    /// The same snapshot features the selection layer keys on
    /// ([`RuntimeSnapshot`]), aggregated per scheduling context.
    pub fn context_loads(&self) -> Vec<CtxLoad> {
        let contexts = self.inner.contexts.read().unwrap();
        contexts
            .iter()
            .enumerate()
            .map(|(id, c)| {
                let members = c.ctx.members();
                let busy = members
                    .iter()
                    .map(|&w| c.ctx.running[w].load(Ordering::Relaxed).min(1))
                    .sum();
                // best-case wait: the backlog of the least-loaded member
                let queued_secs = members
                    .iter()
                    .map(|&w| c.ctx.queued_secs(w))
                    .fold(f64::INFINITY, f64::min);
                CtxLoad {
                    id,
                    name: c.name.clone(),
                    workers: members.len(),
                    queue_depth: c.ctx.pending.load(Ordering::Relaxed).max(0) as usize,
                    busy,
                    queued_secs: if queued_secs.is_finite() { queued_secs } else { 0.0 },
                    tenants: c.ctx.tenants.load(Ordering::Relaxed),
                }
            })
            .collect()
    }

    /// Capture a structural snapshot of the concurrency core and run
    /// the counter audit over it. Takes the reconfiguration lock so no
    /// migration can change membership mid-read, then checks:
    ///
    /// - per-context occupancy ([`validate_occupancy`] — the same
    ///   function the pure model's invariant set uses);
    /// - worker partition: every worker sits in exactly one context's
    ///   member list, and `worker_ctx` agrees with it.
    ///
    /// Errors name the offending context/worker; `Ok` carries the
    /// snapshot the model's differential mode compares against.
    pub fn audited_state(&self) -> Result<AuditedState> {
        let _reconfig = self.inner.reconfig.lock().unwrap();
        let contexts = self.inner.contexts.read().unwrap();
        let total_workers = self.inner.workers.len();
        let mut owner: Vec<Option<CtxId>> = vec![None; total_workers];
        let mut audits = Vec::with_capacity(contexts.len());
        for (id, c) in contexts.iter().enumerate() {
            let mut members = c.ctx.members();
            members.sort_unstable();
            let occupancy: Vec<WorkerOccupancy> = members
                .iter()
                .map(|&w| {
                    (
                        w,
                        self.inner.workers[w].arch,
                        c.ctx.running[w].load(Ordering::Relaxed),
                    )
                })
                .collect();
            if let Err(msg) = validate_occupancy(&occupancy) {
                bail!("context {id} ('{}') failed the counter audit: {msg}", c.name);
            }
            for &w in &members {
                if let Some(prev) = owner[w] {
                    bail!(
                        "worker {w} is a member of both context {prev} and context {id} ('{}')",
                        c.name
                    );
                }
                owner[w] = Some(id);
                let recorded = self.inner.worker_ctx[w].load(Ordering::Relaxed);
                if recorded != id {
                    bail!(
                        "worker {w} is a member of context {id} ('{}') but worker_ctx says {recorded}",
                        c.name
                    );
                }
            }
            audits.push(CtxAudit {
                id,
                name: c.name.clone(),
                members,
                occupancy,
                queue_depth: c.ctx.pending.load(Ordering::Relaxed).max(0) as usize,
            });
        }
        for (w, o) in owner.iter().enumerate() {
            if o.is_none() {
                bail!("worker {w} is not a member of any context (partition leak)");
            }
        }
        Ok(AuditedState {
            contexts: audits,
            total_workers,
        })
    }

    /// Migrate up to `n` workers from context `from` into context `to`
    /// **without quiescing the runtime** — the elastic-capacity
    /// primitive behind `compar autoscale`. Returns how many workers
    /// actually moved (0 when the donor has nothing movable).
    ///
    /// A moving worker finishes (or keeps) whatever task it already
    /// popped from the donor, then re-homes on its next scheduling
    /// iteration; tasks parked in its donor lane are evicted and
    /// re-placed on the remaining members under the donor's migration
    /// gate, so no task strands and the queue-depth / occupancy /
    /// deque-model counters stay exact. Movers are chosen idle-first,
    /// and a worker that is the donor's *last member of its
    /// architecture* never moves (queued work needing that architecture
    /// must keep an executor) — which also means a context never
    /// shrinks to zero workers through this path.
    pub fn move_workers(&self, from: CtxId, to: CtxId, n: usize) -> Result<usize> {
        if from == to {
            bail!("move_workers: source and destination are both context {from}");
        }
        let _reconfig = self.inner.reconfig.lock().unwrap();
        let (src, dst) = {
            let contexts = self.inner.contexts.read().unwrap();
            let src = contexts
                .get(from)
                .cloned()
                .ok_or_else(|| anyhow!("unknown scheduling context {from}"))?;
            let dst = contexts
                .get(to)
                .cloned()
                .ok_or_else(|| anyhow!("unknown scheduling context {to}"))?;
            (src, dst)
        };
        if n == 0 {
            return Ok(0);
        }
        let members = src.ctx.members();
        // mover preference: workers whose architecture the receiver
        // already serves come first — a worker of a foreign arch cannot
        // execute the receiver's queued work and would only dilute its
        // pressure signal — then idle workers (their migration is
        // drain-free), stable by id. Foreign-arch workers still move
        // when nothing else can (deliberate heterogeneous growth).
        let dst_archs = dst.ctx.member_archs();
        let mut cands = members.clone();
        cands.sort_by_key(|&w| {
            let arch = self.inner.workers[w].arch;
            (
                !dst_archs.is_empty() && !dst_archs.contains(&arch),
                src.ctx.running[w].load(Ordering::Relaxed),
                w,
            )
        });
        let mut remaining = members;
        let mut movers: Vec<usize> = Vec::new();
        for w in cands {
            if movers.len() == n {
                break;
            }
            let arch = self.inner.workers[w].arch;
            let same_arch = remaining
                .iter()
                .filter(|&&x| self.inner.workers[x].arch == arch)
                .count();
            if same_arch <= 1 {
                continue; // last of its architecture stays
            }
            remaining.retain(|&x| x != w);
            movers.push(w);
        }
        if movers.is_empty() {
            return Ok(0);
        }
        // 1) shrink the donor under its migration write gate: in-flight
        //    pushes (which hold the read side) finish first, and no new
        //    push can target a mover's lane after the eviction sweep
        {
            let _gate = src.ctx.migration.write().unwrap();
            src.ctx.set_members(remaining);
            for &w in &movers {
                for mut t in src.sched.evict(w) {
                    // undo the deque-model charge; the re-push re-places
                    // (and re-charges) on the remaining members
                    if t.est_cost_ns > 0 {
                        src.ctx.discharge(w, t.est_cost_ns);
                        t.est_cost_ns = 0;
                    }
                    t.chosen_impl = None;
                    src.sched.push(t, &src.ctx);
                }
            }
        }
        // 2) grow the receiver, then re-home the workers: each mover
        //    re-resolves its context on the next worker-loop iteration
        let mut dst_members = dst.ctx.members();
        dst_members.extend(movers.iter().copied());
        dst.ctx.set_members(dst_members);
        for &w in &movers {
            self.inner.worker_ctx[w].store(to, Ordering::Release);
        }
        Ok(movers.len())
    }

    /// Resize context `id` toward `target` member workers by exchanging
    /// workers with the default context (the elastic pool); see
    /// [`Runtime::move_workers`] for the migration semantics. Returns
    /// the context's new worker count, which may fall short of `target`
    /// when the pool cannot supply (or absorb) enough workers.
    pub fn resize_context(&self, id: CtxId, target: usize) -> Result<usize> {
        if id == DEFAULT_CTX {
            bail!("resize_context: context 0 is the elastic pool itself");
        }
        if self.inner.slot(id).is_none() {
            bail!("unknown scheduling context {id}");
        }
        let cur = self.worker_count_in(id);
        match target.cmp(&cur) {
            std::cmp::Ordering::Greater => {
                self.move_workers(DEFAULT_CTX, id, target - cur)?;
            }
            std::cmp::Ordering::Less => {
                self.move_workers(id, DEFAULT_CTX, cur - target)?;
            }
            std::cmp::Ordering::Equal => {}
        }
        Ok(self.worker_count_in(id))
    }

    // ------------------------------------------------------------- data

    pub fn register_data(&self, t: Tensor) -> HandleId {
        self.inner.data.register(t)
    }

    pub fn register_data_named(&self, name: &str, t: Tensor) -> HandleId {
        self.inner.data.register_named(name, t)
    }

    /// Drop a data handle (slot is recycled). The caller must ensure no
    /// in-flight task still names it.
    pub fn unregister_data(&self, id: HandleId) -> Result<()> {
        self.inner.data.unregister(id)
    }

    /// Copy out a handle's current contents (implies wait_all first for
    /// deterministic reads in app code; we do not wait here).
    pub fn snapshot(&self, id: HandleId) -> Result<Tensor> {
        self.inner.data.snapshot(id)
    }

    pub fn data(&self) -> &Arc<DataRegistry> {
        &self.inner.data
    }

    // --------------------------------------------------------- codelets

    pub fn register_codelet(&self, c: Codelet) -> Arc<Codelet> {
        let arc = Arc::new(c);
        self.inner
            .codelets
            .write()
            .unwrap()
            .insert(arc.name.clone(), arc.clone());
        arc
    }

    pub fn codelet(&self, name: &str) -> Option<Arc<Codelet>> {
        self.inner.codelets.read().unwrap().get(name).cloned()
    }

    // ------------------------------------------------------------ tasks

    /// Submit a task. Implicit dependencies (sequential consistency over
    /// its data handles) are resolved here; the task enters its context's
    /// scheduler as soon as they clear.
    pub fn submit(&self, spec: TaskSpec) -> Result<TaskId> {
        // Count the task in-flight *first*: a concurrent create_context
        // requires (and locks out) quiescence, so once this increment
        // lands the context table cannot be repartitioned under us.
        *self.inner.inflight.lock().unwrap() += 1;
        let undo = |this: &Runtime| {
            let mut inflight = this.inner.inflight.lock().unwrap();
            *inflight -= 1;
            if *inflight == 0 {
                this.inner.inflight_cv.notify_all();
            }
        };

        let Some(slot) = self.inner.slot(spec.ctx) else {
            undo(self);
            bail!("unknown scheduling context {}", spec.ctx);
        };
        // validate executability up front (StarPU would hang instead)
        let archs = slot.ctx.member_archs();
        let probe = ReadyTask {
            id: 0,
            codelet: spec.codelet.clone(),
            size: spec.size,
            handles: spec.handles.clone(),
            selector: spec.selector.clone(),
            priority: spec.priority,
            ctx: spec.ctx,
            chosen_impl: None,
            est_cost_ns: 0,
            tag: spec.tag,
            trace: spec.trace,
            enqueued_ns: 0,
        };
        if !archs.iter().any(|&a| slot.ctx.can_run(&probe, a)) {
            undo(self);
            bail!(
                "task on codelet '{}' (size {}) has no selectable implementation \
                 in context '{}' (workers {:?}, policy '{}')",
                spec.codelet.name,
                spec.size,
                slot.name,
                slot.ctx.members(),
                slot.ctx.policy_for(&probe).name()
            );
        }

        let (id, ready) = {
            let mut table = self.inner.tasks.lock().unwrap();
            // record_access needs the task id before insertion; TaskTable
            // assigns ids sequentially, so use the announced next id.
            let next = table.next_id();
            // all-or-nothing: an unknown handle must not leave partial
            // sequential-consistency bookkeeping behind for a task id
            // that is never inserted (and would later be reassigned)
            let deps = match self.inner.data.record_access_all(&spec.handles, next as usize) {
                Ok(d) => d,
                Err(e) => {
                    drop(table);
                    undo(self);
                    return Err(e);
                }
            };
            let mut deps: Vec<TaskId> = deps.into_iter().map(|d| d as TaskId).collect();
            // explicit dependencies (starpu_task_declare_deps analog)
            deps.extend(spec.after.iter().copied());
            deps.sort_unstable();
            deps.dedup();
            let (id, ready) = table.insert(spec, &deps);
            debug_assert_eq!(id, next, "task id drift");
            (id, ready)
        };

        if ready {
            worker::push_ready(&self.inner, id);
        }
        Ok(id)
    }

    /// Block until every submitted task has finished. Returns the first
    /// execution error, if any task failed.
    pub fn wait_all(&self) -> Result<()> {
        let mut inflight = self.inner.inflight.lock().unwrap();
        while *inflight > 0 {
            inflight = self.inner.inflight_cv.wait(inflight).unwrap();
        }
        drop(inflight);
        let table = self.inner.tasks.lock().unwrap();
        if let Some(e) = table.first_error() {
            return Err(anyhow!("task failed: {e}"));
        }
        Ok(())
    }

    /// Block until the given tasks have finished (Done or Failed, or
    /// already reaped). Unlike [`Runtime::wait_all`] this is safe for a
    /// multi-tenant service: it only waits on one request's tasks and
    /// only reports *their* errors.
    pub fn wait_tasks(&self, ids: &[TaskId]) -> Result<()> {
        let mut table = self.inner.tasks.lock().unwrap();
        loop {
            let mut first_err: Option<String> = None;
            let all_done = ids.iter().all(|&id| match table.state(id) {
                None | Some(TaskState::Done) => true,
                Some(TaskState::Failed) => {
                    if first_err.is_none() {
                        first_err = table.error(id);
                    }
                    true
                }
                _ => false,
            });
            if all_done {
                return match first_err {
                    Some(e) => Err(anyhow!("task failed: {e}")),
                    None => Ok(()),
                };
            }
            table = self.inner.tasks_cv.wait(table).unwrap();
        }
    }

    /// Drop bookkeeping for finished tasks (a long-running service reaps
    /// each request's tasks after collecting its results).
    pub fn reap_tasks(&self, ids: &[TaskId]) {
        self.inner.tasks.lock().unwrap().remove_finished(ids);
    }

    pub fn task_state(&self, id: TaskId) -> Option<TaskState> {
        self.inner.tasks.lock().unwrap().state(id)
    }

    // ------------------------------------------------------------ graphs

    /// Submit a whole task DAG with globally planned variant assignments
    /// (Kessler & Dastgeer's *Optimized Composition*; see [`crate::plan`]).
    ///
    /// The [`crate::plan::GraphPlanner`] prices every node's candidates
    /// with the live perf models (analytic device models while cold),
    /// the modeled PCIe cost of each data edge, and the context's
    /// current backlog, then assigns variants jointly to minimize the
    /// graph's modeled makespan. Nodes are released in dependency order
    /// carrying prefer-strength [`Planned`] priors — never pins — and
    /// runs of same-arch nodes share a priority so same-codelet
    /// batching can coalesce them. When the context is contended at
    /// submit time (queue pressure beyond its parallelism), or
    /// `force_greedy` is set, the planner degrades to per-task greedy
    /// and tasks are released under `base_selector` (the context's
    /// policy when `None`).
    pub fn submit_graph(
        &self,
        spec: &GraphSpec,
        ctx: CtxId,
        base_selector: Option<Arc<dyn SelectionPolicy>>,
        force_greedy: bool,
    ) -> Result<GraphRun> {
        if spec.is_empty() {
            bail!("cannot submit an empty graph");
        }
        let slot = self
            .inner
            .slot(ctx)
            .ok_or_else(|| anyhow!("unknown scheduling context {ctx}"))?;

        // planner view of every node
        let mut nodes: Vec<PlanNode> = Vec::with_capacity(spec.len());
        for n in &spec.nodes {
            if n.handles.len() != n.codelet.modes.len() {
                bail!(
                    "graph node '{}': {} handle(s) for codelet '{}' expecting {}",
                    n.name,
                    n.handles.len(),
                    n.codelet.name,
                    n.codelet.modes.len()
                );
            }
            let probe = ReadyTask {
                id: 0,
                codelet: n.codelet.clone(),
                size: n.size,
                handles: n
                    .handles
                    .iter()
                    .copied()
                    .zip(n.codelet.modes.iter().copied())
                    .collect(),
                selector: None,
                priority: 0,
                ctx,
                chosen_impl: None,
                est_cost_ns: 0,
                tag: 0,
                trace: 0,
                enqueued_ns: 0,
            };
            // candidate table: every eligible implementation on every
            // member architecture, priced by the perf models — falling
            // back to the analytic device model so cold codelets still
            // plan instead of defaulting to arbitrary order
            let mut candidates = Vec::new();
            for &arch in &slot.ctx.member_archs() {
                for i in slot.ctx.eligible_impls(&probe, arch) {
                    let imp = &n.codelet.impls[i];
                    if let Some(pin) = n.pinned.as_deref() {
                        if imp.name != pin {
                            continue;
                        }
                    }
                    let est = slot
                        .ctx
                        .exec_estimate(&probe, i)
                        .or_else(|| slot.ctx.recent_estimate(&probe, i))
                        .unwrap_or_else(|| {
                            device::exec_model(&n.codelet.app, &imp.name, n.size)
                        });
                    candidates.push(Candidate {
                        variant: imp.name.clone(),
                        arch: imp.arch,
                        est,
                    });
                }
            }
            if candidates.is_empty() {
                bail!(
                    "graph node '{}' (codelet '{}', size {}) has no selectable \
                     implementation in context '{}'",
                    n.name,
                    n.codelet.name,
                    n.size,
                    slot.name
                );
            }
            // residency pricing: bytes shared with each producer ride
            // that edge; bytes no producer writes are main-memory roots
            let mut edge_bytes = Vec::with_capacity(n.deps.len());
            let mut from_deps: Vec<HandleId> = Vec::new();
            for &d in &n.deps {
                let dep = &spec.nodes[d];
                let mut bytes = 0usize;
                for &h in &n.handles {
                    if dep.handles.contains(&h) {
                        bytes += self.inner.data.byte_size(h)?;
                        from_deps.push(h);
                    }
                }
                edge_bytes.push(bytes);
            }
            let mut root_bytes = 0usize;
            for &h in &n.handles {
                if !from_deps.contains(&h) {
                    root_bytes += self.inner.data.byte_size(h)?;
                }
            }
            nodes.push(PlanNode {
                name: n.name.clone(),
                deps: n.deps.clone(),
                edge_bytes,
                root_bytes,
                candidates,
            });
        }

        // per-arch backlog: the best-case wait on each architecture
        let mut arch_backlog: Vec<(Arch, f64)> = Vec::new();
        for w in slot.ctx.member_workers() {
            let t = slot.ctx.queued_secs(w.id);
            match arch_backlog.iter_mut().find(|(a, _)| *a == w.arch) {
                Some(entry) => entry.1 = entry.1.min(t),
                None => arch_backlog.push((w.arch, t)),
            }
        }
        // degradation signal: queue pressure beyond the partition's
        // parallelism means the snapshot is already stale by the time
        // the whole graph would release — plan per-task instead
        let contended = slot.ctx.pending.load(Ordering::Relaxed).max(0) as usize
            > slot.ctx.member_count();

        let input = PlannerInput {
            nodes,
            arch_backlog,
            contended: contended || force_greedy,
        };
        let plan = GraphPlanner::new().plan(&input)?;

        // observability: planner activity counters (scraped via the v9
        // `metrics` request alongside the taskrt histograms)
        let obs = &self.inner.obs;
        obs.registry
            .counter("plan_graphs_total")
            .fetch_add(1, Ordering::Relaxed);
        obs.registry
            .counter("plan_nodes_total")
            .fetch_add(spec.len() as u64, Ordering::Relaxed);
        let mode_counter = match plan.mode {
            PlanMode::Planned => "plan_planned_total",
            PlanMode::Greedy => "plan_greedy_total",
        };
        obs.registry
            .counter(mode_counter)
            .fetch_add(1, Ordering::Relaxed);

        // release in dependency order; same-span nodes share a priority
        // (higher = earlier spans) so the batcher sees them together
        let mut tasks: Vec<TaskId> = Vec::with_capacity(spec.len());
        for (i, n) in spec.nodes.iter().enumerate() {
            let a = &plan.assignments[i];
            let mut t = TaskSpec::new(n.codelet.clone(), n.handles.clone(), n.size)
                .in_context(ctx)
                .with_tag(i as u64 + 1)
                .with_trace(spec.trace)
                .with_priority((plan.spans - a.span) as i32);
            let after: Vec<TaskId> = n.deps.iter().map(|&d| tasks[d]).collect();
            if !after.is_empty() {
                t = t.after(&after);
            }
            t.selector = match plan.mode {
                PlanMode::Planned => {
                    Some(Arc::new(Planned::with_prior(&a.variant, a.est)) as Arc<dyn SelectionPolicy>)
                }
                PlanMode::Greedy => base_selector.clone(),
            };
            tasks.push(self.submit(t)?);
        }
        Ok(GraphRun { tasks, plan })
    }

    // ------------------------------------------------------- band gossip

    /// Export every context's banded selection state
    /// ([`SelectionPolicy::export_bands`]) as one summary, so graph
    /// plans computed on other shards price variants with this shard's
    /// interference evidence.
    pub fn export_selection_bands(&self) -> Option<Json> {
        let contexts = self.inner.contexts.read().unwrap();
        let mut all = Vec::new();
        for c in contexts.iter() {
            if let Some(Json::Arr(mut a)) = c.ctx.selector.export_bands() {
                all.append(&mut a);
            }
        }
        if all.is_empty() {
            None
        } else {
            Some(Json::Arr(all))
        }
    }

    /// Merge a peer's banded selection summary into every context's
    /// policy; returns the number of buckets accepted.
    pub fn import_selection_bands(&self, bands: &Json) -> usize {
        let contexts = self.inner.contexts.read().unwrap();
        contexts
            .iter()
            .map(|c| c.ctx.selector.import_bands(bands))
            .sum()
    }

    // -------------------------------------------------------- snapshots

    /// Register a serve-layer session: the co-tenant count feeds every
    /// context's [`RuntimeSnapshot`]. Pair with
    /// [`Runtime::tenant_finished`].
    pub fn tenant_started(&self) {
        self.inner.tenants.fetch_add(1, Ordering::Relaxed);
    }

    /// Unregister a serve-layer session (see [`Runtime::tenant_started`]).
    pub fn tenant_finished(&self) {
        let _ = self
            .inner
            .tenants
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
    }

    /// Live serve-layer sessions sharing this runtime.
    pub fn tenants(&self) -> usize {
        self.inner.tenants.load(Ordering::Relaxed)
    }

    /// Workers currently executing a task (occupancy across all
    /// scheduling contexts — each worker executes from exactly one).
    pub fn busy_workers(&self) -> usize {
        let contexts = self.inner.contexts.read().unwrap();
        contexts
            .iter()
            .map(|c| {
                c.ctx
                    .running
                    .iter()
                    .map(|r| r.load(Ordering::Relaxed).min(1))
                    .sum::<usize>()
            })
            .sum()
    }

    /// Total workers in the machine topology.
    pub fn worker_count(&self) -> usize {
        self.inner.workers.len()
    }

    /// Tasks queued (pushed, not yet popped) across every context.
    pub fn queued_tasks(&self) -> usize {
        let contexts = self.inner.contexts.read().unwrap();
        contexts
            .iter()
            .map(|c| c.ctx.pending.load(Ordering::Relaxed).max(0) as usize)
            .sum()
    }

    // ---------------------------------------------------------- metrics

    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    /// The runtime's live observability plane: metrics registry,
    /// selection-decision audit ring and trace ring. Shared with every
    /// scheduling context's `SchedCtx`, so worker-side observations and
    /// serve-layer request spans land in one place.
    pub fn obs(&self) -> &Arc<crate::obs::Obs> {
        &self.inner.obs
    }

    pub fn drain_results(&self) -> Vec<TaskResult> {
        self.inner.metrics.drain_results()
    }

    pub fn perf_models(&self) -> &Arc<PerfModels> {
        &self.inner.perf
    }

    /// Export the execution trace (chrome://tracing JSON) of everything
    /// recorded so far — StarPU's FxT trace analog.
    pub fn export_chrome_trace(&self, path: &std::path::Path) -> Result<()> {
        trace::export_chrome_trace(&self.inner.metrics.results(), &self.inner.workers, path)
    }

    /// Persist perf models to the configured directory.
    pub fn save_perf_models(&self) -> Result<()> {
        if let Some(dir) = &self.inner.config.perfmodel_dir {
            self.inner.perf.save(&dir.join("models.json"))?;
        }
        Ok(())
    }

    /// Graceful shutdown: waits for queues to drain, then joins workers.
    pub fn shutdown(mut self) -> Result<()> {
        self.wait_all()?;
        self.inner.shutdown.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.save_perf_models()
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}
