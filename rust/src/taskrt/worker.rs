//! Worker loop: pop a ready task from this worker's current scheduling
//! context, acquire its data on this device's memory node (MSI coherence
//! + transfer accounting), execute the chosen implementation variant for
//! real, attribute modeled device time, feed the performance model,
//! release dependents.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::codelet::{ExecBuffers, ImplKind};
use super::config::TimeMode;
use super::device;
use super::metrics::TaskResult;
use super::scheduler::{ReadyTask, WorkerInfo};
use super::{ContextSlot, Inner};
use crate::runtime::Tensor;

/// Decrements a worker's in-flight counter on drop, so the occupancy
/// signal clears even when an execution body errors out early.
struct Busy<'a>(&'a std::sync::atomic::AtomicUsize);

impl Drop for Busy<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

pub(crate) fn run(inner: Arc<Inner>, me: WorkerInfo) {
    loop {
        // Re-resolve the context each iteration: create_context may have
        // reassigned this worker (only while the runtime is quiescent).
        let cid = inner.worker_ctx[me.id].load(Ordering::Acquire);
        let Some(slot) = inner.slot(cid) else {
            // context table not yet populated (startup race): spin gently
            if inner.shutdown.load(Ordering::SeqCst) {
                return;
            }
            std::thread::yield_now();
            continue;
        };
        let task = slot.sched.pop(me.id, &slot.ctx, inner.config.poll);
        match task {
            Some(t) => {
                // popped: leave the context's queue-depth counter (the
                // selection snapshots' context-wide pressure signal).
                // May transiently reach -1 when this pop races the
                // producer's post-push increment; snapshots clamp at 0.
                slot.ctx.pending.fetch_sub(1, Ordering::Relaxed);
                if t.enqueued_ns > 0 {
                    let waited = slot.ctx.obs.now_nanos().saturating_sub(t.enqueued_ns);
                    slot.ctx
                        .obs
                        .queue_wait_seconds()
                        .observe(waited as f64 / 1e9);
                }
                execute(&inner, &me, &slot, t);
            }
            None => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

fn execute(inner: &Arc<Inner>, me: &WorkerInfo, slot: &ContextSlot, task: ReadyTask) {
    // NOTE §Perf: the task is not flipped to Running in the table here —
    // that cost a global table lock per task for purely informational
    // state; Ready->Done is observationally equivalent for callers.
    let outcome = execute_body(inner, me, slot, &task);

    // undo the deque-model charge now that the task left the queue
    if task.est_cost_ns > 0 {
        slot.ctx.discharge(me.id, task.est_cost_ns);
    }

    let error = match outcome {
        Ok(result) => {
            inner.metrics.record(result);
            None
        }
        Err(e) => {
            inner.metrics.record_failure();
            Some(format!("{e:#}"))
        }
    };

    // complete + release dependents
    let ready = {
        let mut table = inner.tasks.lock().unwrap();
        table.complete(task.id, error)
    };
    inner.tasks_cv.notify_all();
    for id in ready {
        push_ready(inner, id);
    }

    // in-flight accounting for wait_all
    {
        let mut inflight = inner.inflight.lock().unwrap();
        *inflight -= 1;
        if *inflight == 0 {
            inner.inflight_cv.notify_all();
        }
    }
}

pub(crate) fn push_ready(inner: &Arc<Inner>, id: super::task::TaskId) {
    let spec = {
        let table = inner.tasks.lock().unwrap();
        table.records.get(&id).map(|r| r.spec.clone())
    };
    if let Some(spec) = spec {
        let slot = inner
            .slot(spec.ctx)
            .expect("context slots are never removed");
        let rt = ReadyTask {
            id,
            codelet: spec.codelet.clone(),
            size: spec.size,
            handles: spec.handles.clone(),
            selector: spec.selector.clone(),
            priority: spec.priority,
            ctx: spec.ctx,
            chosen_impl: None,
            est_cost_ns: 0,
            tag: spec.tag,
            trace: spec.trace,
            enqueued_ns: slot.ctx.obs.now_nanos(),
        };
        // count the task into the context's queue depth *after* the
        // push: model-aware schedulers run their selection queries
        // inside push(), and the task being placed must not count
        // itself as pressure — otherwise the idle band would be
        // unreachable on the decision path and banded policies would
        // learn into a band that selection never consults.
        // The migration read gate makes the placement atomic against a
        // concurrent worker migration: without it, a push could target a
        // worker that leaves the partition between the placement scan
        // and the lane insert, stranding the task after the migration's
        // eviction sweep already ran.
        let _gate = slot.ctx.migration.read().unwrap();
        slot.sched.push(rt, &slot.ctx);
        slot.ctx.pending.fetch_add(1, Ordering::Relaxed);
    }
}

fn execute_body(
    inner: &Arc<Inner>,
    me: &WorkerInfo,
    slot: &ContextSlot,
    task: &ReadyTask,
) -> Result<TaskResult> {
    let codelet = &task.codelet;

    // choose the implementation: model-aware schedulers already asked
    // the selection policy at push time; everyone else asks it now
    let impl_idx = match task.chosen_impl {
        Some(i) if slot.ctx.impl_eligible(task, i, me.arch) => i,
        _ => slot
            .ctx
            .select_impl(task, me.arch)
            .map(|c| c.impl_idx)
            .ok_or_else(|| {
                anyhow!(
                    "no implementation of '{}' (size {}) selectable on {} worker {} \
                     (context '{}', policy '{}')",
                    codelet.name,
                    task.size,
                    me.arch.name(),
                    me.id,
                    slot.name,
                    slot.ctx.policy_for(task).name()
                )
            })?,
    };
    let imp = &codelet.impls[impl_idx];

    // acquire data on this memory node (coherence + transfer accounting)
    let mut transfer_bytes = 0usize;
    for (h, m) in &task.handles {
        transfer_bytes += inner.data.acquire(*h, me.mem_node, *m)?;
    }

    // occupancy: visible to concurrent selection snapshots while the
    // body runs (incremented after selection so a worker's own choice
    // never counts itself as in-flight pressure)
    slot.ctx.running[me.id].fetch_add(1, Ordering::Relaxed);
    let _busy = Busy(&slot.ctx.running[me.id]);

    // execute for real
    let t_start = inner.epoch.elapsed().as_secs_f64();
    let t0 = Instant::now();
    match &imp.kind {
        ImplKind::Native(f) => {
            let tensors = task
                .handles
                .iter()
                .map(|(h, _)| inner.data.tensor(*h))
                .collect::<Result<Vec<_>>>()?;
            let bufs = ExecBuffers {
                tensors,
                modes: task.handles.iter().map(|(_, m)| *m).collect(),
                size: task.size,
            };
            f(&bufs)?;
        }
        ImplKind::Artifact { artifact_variant } => {
            let manifest = inner
                .manifest
                .as_ref()
                .ok_or_else(|| anyhow!("artifact variant without a manifest"))?;
            let meta = manifest
                .find(&codelet.app, artifact_variant, task.size)
                .ok_or_else(|| {
                    anyhow!(
                        "no artifact {}/{} at size {}",
                        codelet.app,
                        artifact_variant,
                        task.size
                    )
                })?
                .clone();
            let xla = inner
                .xla
                .as_ref()
                .ok_or_else(|| anyhow!("xla service not running"))?;
            // inputs = readable parameters, in declaration order
            let inputs: Vec<Tensor> = task
                .handles
                .iter()
                .filter(|(_, m)| m.reads())
                .map(|(h, _)| inner.data.snapshot(*h))
                .collect::<Result<Vec<_>>>()?;
            let (outputs, _svc_time) = xla.run(&meta, inputs)?;
            // outputs map onto writable parameters, in declaration order
            let writers: Vec<usize> = (0..task.handles.len())
                .filter(|&i| task.handles[i].1.writes())
                .collect();
            if outputs.len() != writers.len() {
                return Err(anyhow!(
                    "{}: artifact returned {} outputs for {} writable parameters",
                    meta.name,
                    outputs.len(),
                    writers.len()
                ));
            }
            for (slot_idx, out) in writers.into_iter().zip(outputs) {
                let (h, _) = task.handles[slot_idx];
                let storage = inner.data.tensor(h)?;
                let mut guard = storage.lock().unwrap();
                if guard.shape() != out.shape() {
                    return Err(anyhow!(
                        "{}: output shape {:?} != handle shape {:?}",
                        meta.name,
                        out.shape(),
                        guard.shape()
                    ));
                }
                *guard = out;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(_busy); // the feedback snapshot must not count this task

    // attribute device time (DESIGN.md §3)
    let (modeled_exec, modeled_transfer) = match inner.config.time_mode {
        TimeMode::Modeled => {
            let base = device::exec_model(&codelet.app, &imp.name, task.size);
            (
                inner.noise.apply(base),
                device::transfer_model(transfer_bytes),
            )
        }
        TimeMode::Wall => (wall, 0.0),
    };

    // history model learns the *execution* component only; dmda adds
    // transfer separately at placement time. The governing selection
    // policy hears about the measurement too (online-learning loop),
    // through a full SelectionQuery so context-aware policies know
    // which load band the observation belongs to.
    inner
        .perf
        .record(&codelet.name, &imp.name, task.size, modeled_exec);
    slot.ctx.feedback(task, me.arch, &imp.name, modeled_exec);

    // observability: latency histograms + a request-correlated task
    // span into the live trace ring (non-blocking by construction)
    slot.ctx.obs.exec_seconds().observe(wall);
    if transfer_bytes > 0 {
        slot.ctx.obs.transfer_seconds().observe(modeled_transfer);
    }
    slot.ctx.obs.trace.push(crate::obs::SpanEvent {
        name: format!("{}:{}", codelet.name, imp.name),
        cat: "task",
        lane: me.id as u64,
        lane_name: format!("worker{}", me.id),
        trace: task.trace,
        t_start,
        t_end: t_start + wall,
    });

    Ok(TaskResult {
        task: task.id,
        codelet: codelet.name.clone(),
        variant: imp.name.clone(),
        worker: me.id,
        ctx: task.ctx,
        size: task.size,
        wall,
        modeled_exec,
        modeled_transfer,
        transfer_bytes,
        t_start,
        t_end: t_start + wall,
        tag: task.tag,
        trace: task.trace,
    })
}
