//! Execution metrics: per-task results and aggregate counters.
//!
//! The bench harness consumes [`TaskResult`] records to build the Fig. 1
//! series (modeled time per app/size/configuration) and the variant-
//! selection traces the paper discusses in §3.2.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::task::TaskId;

/// Outcome of one executed task.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: TaskId,
    pub codelet: String,
    /// Variant name actually executed ("omp", "cuda", ...).
    pub variant: String,
    pub worker: usize,
    /// Scheduling context the task ran under.
    pub ctx: crate::taskrt::CtxId,
    pub size: usize,
    /// Wall-clock execution on this machine (seconds).
    pub wall: f64,
    /// Modeled device execution time (seconds) — DESIGN.md §3.
    pub modeled_exec: f64,
    /// Modeled PCIe transfer time (seconds).
    pub modeled_transfer: f64,
    pub transfer_bytes: usize,
    /// Wall-clock execution window relative to the runtime epoch
    /// (seconds) — consumed by the trace exporter.
    pub t_start: f64,
    pub t_end: f64,
    /// Application tag from [`super::task::TaskSpec::tag`] (chunk
    /// sequence number for stream pipeline tasks; 0 = untagged).
    pub tag: u64,
    /// Cross-layer trace id from [`super::task::TaskSpec::trace`]
    /// (0 = untraced) — lets the chrome-trace exporter and the live
    /// `dump_trace` ring attribute this execution to its originating
    /// request.
    pub trace: u64,
}

impl TaskResult {
    pub fn modeled_total(&self) -> f64 {
        self.modeled_exec + self.modeled_transfer
    }
}

/// Shared metrics sink.
#[derive(Default)]
pub struct Metrics {
    pub tasks_executed: AtomicUsize,
    pub tasks_failed: AtomicUsize,
    pub bytes_transferred: AtomicU64,
    results: Mutex<Vec<TaskResult>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Self::default()
    }

    pub fn record(&self, r: TaskResult) {
        self.tasks_executed.fetch_add(1, Ordering::Relaxed);
        self.bytes_transferred
            .fetch_add(r.transfer_bytes as u64, Ordering::Relaxed);
        self.results.lock().unwrap().push(r);
    }

    pub fn record_failure(&self) {
        self.tasks_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Take all accumulated task results (clears the buffer).
    pub fn drain_results(&self) -> Vec<TaskResult> {
        std::mem::take(&mut self.results.lock().unwrap())
    }

    /// Take only the results for the given task ids, leaving everything
    /// else buffered — the per-request extraction the component service
    /// uses so concurrent requests don't steal each other's results.
    pub fn take_results_for(&self, ids: &[TaskId]) -> Vec<TaskResult> {
        let wanted: std::collections::BTreeSet<TaskId> = ids.iter().copied().collect();
        let mut guard = self.results.lock().unwrap();
        let mut taken = Vec::new();
        guard.retain(|r| {
            if wanted.contains(&r.task) {
                taken.push(r.clone());
                false
            } else {
                true
            }
        });
        taken.sort_by_key(|r| r.task);
        taken
    }

    /// Peek without clearing.
    pub fn results(&self) -> Vec<TaskResult> {
        self.results.lock().unwrap().clone()
    }

    /// variant -> execution count (the selection histogram of §3.2).
    pub fn variant_histogram(&self) -> BTreeMap<String, usize> {
        let mut h = BTreeMap::new();
        for r in self.results.lock().unwrap().iter() {
            *h.entry(r.variant.clone()).or_insert(0) += 1;
        }
        h
    }

    /// context id -> execution count (multi-tenant accounting).
    pub fn ctx_histogram(&self) -> BTreeMap<crate::taskrt::CtxId, usize> {
        let mut h = BTreeMap::new();
        for r in self.results.lock().unwrap().iter() {
            *h.entry(r.ctx).or_insert(0) += 1;
        }
        h
    }

    /// Sum of modeled times (exec + transfer) over all results.
    pub fn modeled_total(&self) -> f64 {
        self.results
            .lock()
            .unwrap()
            .iter()
            .map(|r| r.modeled_total())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(variant: &str, t: f64) -> TaskResult {
        TaskResult {
            task: 0,
            codelet: "c".into(),
            variant: variant.into(),
            worker: 0,
            ctx: 0,
            size: 64,
            wall: t,
            modeled_exec: t,
            modeled_transfer: 0.1,
            transfer_bytes: 256,
            t_start: 0.0,
            t_end: t,
            tag: 0,
            trace: 0,
        }
    }

    #[test]
    fn aggregates() {
        let m = Metrics::new();
        m.record(result("omp", 1.0));
        m.record(result("cuda", 2.0));
        m.record(result("cuda", 3.0));
        assert_eq!(m.tasks_executed.load(Ordering::Relaxed), 3);
        assert_eq!(m.bytes_transferred.load(Ordering::Relaxed), 768);
        let h = m.variant_histogram();
        assert_eq!(h["cuda"], 2);
        assert_eq!(h["omp"], 1);
        assert!((m.modeled_total() - 6.3).abs() < 1e-9);
    }

    #[test]
    fn drain_clears() {
        let m = Metrics::new();
        m.record(result("omp", 1.0));
        assert_eq!(m.drain_results().len(), 1);
        assert!(m.drain_results().is_empty());
    }

    #[test]
    fn take_results_for_is_selective() {
        let m = Metrics::new();
        for (task, ctx) in [(7, 0), (8, 1), (9, 1)] {
            let mut r = result("omp", 1.0);
            r.task = task;
            r.ctx = ctx;
            m.record(r);
        }
        let taken = m.take_results_for(&[9, 7]);
        assert_eq!(
            taken.iter().map(|r| r.task).collect::<Vec<_>>(),
            vec![7, 9],
            "sorted by task id"
        );
        // untouched result still buffered
        assert_eq!(m.results().len(), 1);
        assert_eq!(m.results()[0].task, 8);
        assert_eq!(m.ctx_histogram()[&1], 1);
    }
}
