//! Data handles and coherence — the StarPU data-management analog.
//!
//! Applications register tensors once (`starpu_vector_data_register` /
//! `starpu_matrix_data_register` in the generated glue); tasks then name
//! handles plus an access mode. The registry tracks, per handle, which
//! memory nodes hold a valid copy (MSI-style: main memory is node 0,
//! each CUDA device has its own node), so the transfer engine can charge
//! PCIe time only for actual movements — exactly what StarPU's dmda
//! scheduler feeds its transfer model with.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, Result};

use crate::runtime::Tensor;

/// Access mode of one task parameter (paper `access_mode` clause).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessMode {
    Read,
    Write,
    ReadWrite,
}

impl AccessMode {
    pub fn parse(s: &str) -> Option<AccessMode> {
        match s.to_ascii_lowercase().as_str() {
            "read" | "r" => Some(AccessMode::Read),
            "write" | "w" => Some(AccessMode::Write),
            "readwrite" | "rw" => Some(AccessMode::ReadWrite),
            _ => None,
        }
    }

    pub fn reads(&self) -> bool {
        matches!(self, AccessMode::Read | AccessMode::ReadWrite)
    }

    pub fn writes(&self) -> bool {
        matches!(self, AccessMode::Write | AccessMode::ReadWrite)
    }
}

/// Opaque handle id (index into the registry).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct HandleId(pub usize);

/// Memory node id: 0 = main memory (CPU), 1.. = device memories.
pub type MemNode = usize;

pub const MAIN_MEMORY: MemNode = 0;

struct HandleEntry {
    tensor: Arc<Mutex<Tensor>>,
    /// Nodes currently holding a valid copy.
    valid: Vec<MemNode>,
    /// Sequential-consistency bookkeeping (implicit dependencies):
    /// the last task that wrote this handle, and readers since then.
    last_writer: Option<usize>,
    readers_since_write: Vec<usize>,
}

/// Registry of all application data known to the runtime.
///
/// Slots are recycled: [`DataRegistry::unregister`] frees an entry and a
/// later `register` reuses its index, so a long-running service that
/// registers fresh handles per request stays bounded in memory.
#[derive(Default)]
pub struct DataRegistry {
    entries: RwLock<Vec<Option<HandleEntry>>>,
    /// Indices of unregistered slots available for reuse.
    free: Mutex<Vec<usize>>,
    names: Mutex<HashMap<String, HandleId>>,
}

impl DataRegistry {
    pub fn new() -> DataRegistry {
        Self::default()
    }

    /// Register a tensor; it starts valid only in main memory.
    pub fn register(&self, tensor: Tensor) -> HandleId {
        let entry = HandleEntry {
            tensor: Arc::new(Mutex::new(tensor)),
            valid: vec![MAIN_MEMORY],
            last_writer: None,
            readers_since_write: Vec::new(),
        };
        let mut entries = self.entries.write().unwrap();
        if let Some(slot) = self.free.lock().unwrap().pop() {
            entries[slot] = Some(entry);
            return HandleId(slot);
        }
        let id = HandleId(entries.len());
        entries.push(Some(entry));
        id
    }

    /// Register with a debug name (used by generated glue).
    pub fn register_named(&self, name: &str, tensor: Tensor) -> HandleId {
        let id = self.register(tensor);
        self.names.lock().unwrap().insert(name.to_string(), id);
        id
    }

    /// Drop a handle; its slot is recycled by a later `register`. Callers
    /// must not unregister while tasks naming the handle are in flight.
    pub fn unregister(&self, id: HandleId) -> Result<()> {
        let mut entries = self.entries.write().unwrap();
        match entries.get_mut(id.0) {
            Some(slot) if slot.is_some() => {
                *slot = None;
                self.names.lock().unwrap().retain(|_, v| *v != id);
                self.free.lock().unwrap().push(id.0);
                Ok(())
            }
            _ => Err(anyhow!("unknown handle {id:?}")),
        }
    }

    pub fn lookup(&self, name: &str) -> Option<HandleId> {
        self.names.lock().unwrap().get(name).copied()
    }

    /// Live (registered, not-yet-unregistered) handle count.
    pub fn len(&self) -> usize {
        self.entries
            .read()
            .unwrap()
            .iter()
            .filter(|e| e.is_some())
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn with_entry<T>(&self, id: HandleId, f: impl FnOnce(&mut HandleEntry) -> T) -> Result<T> {
        let mut entries = self.entries.write().unwrap();
        entries
            .get_mut(id.0)
            .and_then(|e| e.as_mut())
            .map(f)
            .ok_or_else(|| anyhow!("unknown handle {id:?}"))
    }

    /// Shared reference to the tensor storage.
    pub fn tensor(&self, id: HandleId) -> Result<Arc<Mutex<Tensor>>> {
        let entries = self.entries.read().unwrap();
        entries
            .get(id.0)
            .and_then(|e| e.as_ref())
            .map(|e| e.tensor.clone())
            .ok_or_else(|| anyhow!("unknown handle {id:?}"))
    }

    /// Clone the current contents ("unregister + fetch" in StarPU terms).
    pub fn snapshot(&self, id: HandleId) -> Result<Tensor> {
        Ok(self.tensor(id)?.lock().unwrap().clone())
    }

    /// Byte size of the handle's tensor.
    pub fn byte_size(&self, id: HandleId) -> Result<usize> {
        Ok(self.tensor(id)?.lock().unwrap().byte_size())
    }

    /// Bytes that must move to make `id` valid on `node` (0 if resident).
    pub fn transfer_bytes(&self, id: HandleId, node: MemNode) -> Result<usize> {
        let entries = self.entries.read().unwrap();
        let e = entries
            .get(id.0)
            .and_then(|e| e.as_ref())
            .ok_or_else(|| anyhow!("unknown handle {id:?}"))?;
        if e.valid.contains(&node) {
            Ok(0)
        } else {
            Ok(e.tensor.lock().unwrap().byte_size())
        }
    }

    /// Make `id` valid on `node` for the given access, applying MSI rules:
    /// a read adds `node` to the valid set; a write invalidates all other
    /// copies. Returns the bytes actually transferred (for accounting).
    pub fn acquire(&self, id: HandleId, node: MemNode, mode: AccessMode) -> Result<usize> {
        self.with_entry(id, |e| {
            let moved = if e.valid.contains(&node) {
                0
            } else {
                e.tensor.lock().unwrap().byte_size()
            };
            if mode.writes() {
                e.valid.clear();
                e.valid.push(node);
            } else if !e.valid.contains(&node) {
                e.valid.push(node);
            }
            moved
        })
    }

    /// Nodes currently holding a valid copy (for tests/inspection).
    pub fn valid_nodes(&self, id: HandleId) -> Result<Vec<MemNode>> {
        let entries = self.entries.read().unwrap();
        entries
            .get(id.0)
            .and_then(|e| e.as_ref())
            .map(|e| e.valid.clone())
            .ok_or_else(|| anyhow!("unknown handle {id:?}"))
    }

    /// Sequential-consistency bookkeeping for one (handle, mode) access.
    fn record_one(e: &mut HandleEntry, task: usize, mode: AccessMode, deps: &mut Vec<usize>) {
        if mode.writes() {
            // write-after-read + write-after-write
            deps.extend(e.readers_since_write.iter().copied());
            if let Some(w) = e.last_writer {
                deps.push(w);
            }
            e.last_writer = Some(task);
            e.readers_since_write.clear();
        } else {
            // read-after-write
            if let Some(w) = e.last_writer {
                deps.push(w);
            }
            e.readers_since_write.push(task);
        }
    }

    /// Implicit-dependency bookkeeping (StarPU sequential consistency):
    /// returns the task ids the new access must wait for.
    pub fn record_access(&self, id: HandleId, task: usize, mode: AccessMode) -> Result<Vec<usize>> {
        self.with_entry(id, |e| {
            let mut deps = Vec::new();
            Self::record_one(e, task, mode, &mut deps);
            deps.sort_unstable();
            deps.dedup();
            deps.retain(|&t| t != task);
            deps
        })
    }

    /// Record all of one task's accesses atomically: every handle is
    /// validated up front under a single registry lock, so a failure
    /// (unknown/unregistered handle) mutates *no* bookkeeping — an
    /// aborted submit must not leave a never-inserted task id behind as
    /// a handle's `last_writer`.
    pub fn record_access_all(
        &self,
        handles: &[(HandleId, AccessMode)],
        task: usize,
    ) -> Result<Vec<usize>> {
        let mut entries = self.entries.write().unwrap();
        for (h, _) in handles {
            if entries.get(h.0).and_then(|e| e.as_ref()).is_none() {
                return Err(anyhow!("unknown handle {h:?}"));
            }
        }
        let mut deps = Vec::new();
        for (h, m) in handles {
            let e = entries
                .get_mut(h.0)
                .and_then(|e| e.as_mut())
                .expect("validated above");
            Self::record_one(e, task, *m, &mut deps);
        }
        deps.sort_unstable();
        deps.dedup();
        deps.retain(|&t| t != task);
        Ok(deps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> (DataRegistry, HandleId) {
        let r = DataRegistry::new();
        let id = r.register(Tensor::vector(vec![1.0, 2.0, 3.0]));
        (r, id)
    }

    #[test]
    fn register_and_snapshot() {
        let (r, id) = reg();
        assert_eq!(r.snapshot(id).unwrap().data(), &[1.0, 2.0, 3.0]);
        assert_eq!(r.byte_size(id).unwrap(), 12);
    }

    #[test]
    fn named_lookup() {
        let r = DataRegistry::new();
        let id = r.register_named("arr", Tensor::vector(vec![0.0]));
        assert_eq!(r.lookup("arr"), Some(id));
        assert_eq!(r.lookup("nope"), None);
    }

    #[test]
    fn msi_read_then_write() {
        let (r, id) = reg();
        // initially valid only on node 0
        assert_eq!(r.valid_nodes(id).unwrap(), vec![0]);
        // read on node 1 -> copy, both valid
        let moved = r.acquire(id, 1, AccessMode::Read).unwrap();
        assert_eq!(moved, 12);
        assert_eq!(r.valid_nodes(id).unwrap(), vec![0, 1]);
        // second read on node 1 -> no movement
        assert_eq!(r.acquire(id, 1, AccessMode::Read).unwrap(), 0);
        // write on node 1 -> invalidates node 0
        r.acquire(id, 1, AccessMode::ReadWrite).unwrap();
        assert_eq!(r.valid_nodes(id).unwrap(), vec![1]);
        // read back on node 0 -> transfer again
        assert_eq!(r.acquire(id, 0, AccessMode::Read).unwrap(), 12);
    }

    #[test]
    fn transfer_bytes_matches_acquire() {
        let (r, id) = reg();
        assert_eq!(r.transfer_bytes(id, 1).unwrap(), 12);
        r.acquire(id, 1, AccessMode::Read).unwrap();
        assert_eq!(r.transfer_bytes(id, 1).unwrap(), 0);
    }

    #[test]
    fn implicit_deps_raw_war_waw() {
        let (r, id) = reg();
        // t0 writes, t1 reads (RAW on t0), t2 reads, t3 writes (WAR on t1,t2)
        assert!(r.record_access(id, 0, AccessMode::Write).unwrap().is_empty());
        assert_eq!(r.record_access(id, 1, AccessMode::Read).unwrap(), vec![0]);
        assert_eq!(r.record_access(id, 2, AccessMode::Read).unwrap(), vec![0]);
        let deps = r.record_access(id, 3, AccessMode::Write).unwrap();
        assert_eq!(deps, vec![0, 1, 2]);
        // t4 reads -> RAW on t3 only
        assert_eq!(r.record_access(id, 4, AccessMode::Read).unwrap(), vec![3]);
    }

    #[test]
    fn record_access_all_is_atomic() {
        let r = DataRegistry::new();
        let a = r.register(Tensor::vector(vec![1.0]));
        let b = r.register(Tensor::vector(vec![2.0]));
        r.unregister(b).unwrap();
        // writer of a in flight as task 0
        assert!(r.record_access(a, 0, AccessMode::Write).unwrap().is_empty());
        // task 1 names a valid and an unregistered handle: must fail
        // WITHOUT touching a's bookkeeping
        let err = r.record_access_all(&[(a, AccessMode::Write), (b, AccessMode::Read)], 1);
        assert!(err.is_err());
        // a's last_writer is still task 0, not the phantom task 1
        assert_eq!(r.record_access(a, 2, AccessMode::Read).unwrap(), vec![0]);
        // and the happy path aggregates deps across handles
        let c = r.register(Tensor::vector(vec![3.0]));
        let deps = r
            .record_access_all(&[(a, AccessMode::Write), (c, AccessMode::Read)], 3)
            .unwrap();
        assert_eq!(deps, vec![0, 2]);
    }

    #[test]
    fn unregister_recycles_slots() {
        let r = DataRegistry::new();
        let a = r.register_named("a", Tensor::vector(vec![1.0]));
        let b = r.register(Tensor::vector(vec![2.0]));
        assert_eq!(r.len(), 2);
        r.unregister(a).unwrap();
        assert_eq!(r.len(), 1);
        assert!(r.snapshot(a).is_err(), "stale handle must error");
        assert!(r.unregister(a).is_err(), "double unregister must error");
        assert_eq!(r.lookup("a"), None, "name mapping dropped");
        // slot is reused, other handles untouched
        let c = r.register(Tensor::vector(vec![3.0]));
        assert_eq!(c, a, "freed slot reused");
        assert_eq!(r.snapshot(c).unwrap().data(), &[3.0]);
        assert_eq!(r.snapshot(b).unwrap().data(), &[2.0]);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn access_mode_parse() {
        assert_eq!(AccessMode::parse("read"), Some(AccessMode::Read));
        assert_eq!(AccessMode::parse("RW"), Some(AccessMode::ReadWrite));
        assert_eq!(AccessMode::parse("x"), None);
    }
}
