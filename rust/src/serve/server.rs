//! The persistent component service: accepts task-graph requests from
//! many concurrent clients over TCP, routes each request to a
//! scheduling context, batches same-codelet requests, enforces an
//! admission cap, and drains gracefully on shutdown.
//!
//! Two transports run the same session state machine (v7, see
//! [`crate::serve::transport`]): the default **threads** path below
//! (one blocking thread per connection) and the **epoll** path in
//! `server_mux.rs` (a readiness event loop multiplexing every session
//! on one thread, with pooled buffers and coalesced vectored writes).
//! Request parsing and response encoding are pure functions over
//! buffers ([`handle_frame`] / [`send_batch`]) shared by both. Each
//! session's wire framing (ndjson or length-prefixed binary) is
//! negotiated in `hello`.
//!
//! ```text
//! client ──TCP──▶ session (thread | event loop) ──▶ gate ──▶ batcher
//!                                                          │ (same-app
//!                                                          ▼  batches)
//!                                     dispatcher ──▶ taskrt submit
//!                                                          │
//!                         completion thread ◀── wait_tasks ┘
//!                 (verify · reply · unregister · reap · release gate)
//! ```

use std::collections::{BTreeMap, HashMap};
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::protocol::{
    self, AutoscaleCtxDesc, AutoscaleResp, CtxDesc, DecisionsResp, GraphDoneResp, GraphNodeReport,
    MetricsResp, Request, Response, ResultResp, StatsResp, StreamAckResp, StreamClosedResp,
    StreamCreditResp, StreamOpenReq, StreamOpenedResp, SubmitGraphReq, SubmitReq, TraceResp,
    PROTOCOL_VERSION,
};
use super::transport::codec::{encode_frame, FrameDecoder, Framing};
#[cfg(unix)]
use super::transport::event_loop::Outbox;
use super::transport::TransportKind;
use crate::util::json::Json;

#[cfg(unix)]
#[path = "server_mux.rs"]
mod mux;
use crate::apps;
use crate::autoscale::{AutoscaleOptions, AutoscaleShared, Autoscaler, ScaleTarget};
use crate::obs::SpanEvent;
use crate::plan::{GraphSpec, PlanMode};
use crate::runtime::Manifest;
use crate::stream::{
    BacklogModel, CreditController, LatencyTrack, StreamShared, StreamSpec, Windower, BASE_CREDIT,
};
use crate::taskrt::{
    Arch, Codelet, Config, CtxId, CtxLoad, HandleId, Runtime, SchedPolicy, SelectionPolicy,
    SelectorKind, TaskId, TaskSpec, VALID_SELECTORS,
};

// ----------------------------------------------------------- configuration

/// One requested context partition: `count` workers of `arch`, with an
/// optional per-context variant-selection policy (tenants can run
/// different policies); scheduler policy inherits
/// [`ServeOptions::sched`].
#[derive(Debug, Clone, PartialEq)]
pub struct CtxSpec {
    pub name: String,
    pub count: usize,
    pub arch: Arch,
    /// Variant-selection policy; `None` = [`ServeOptions::selector`].
    pub selector: Option<SelectorKind>,
}

/// Parse `--contexts cpu:4,gpu:1,tenant:2:epsilon` — names containing
/// "gpu" or "cuda" take CUDA-analog workers, everything else CPU
/// workers; the optional third field picks that context's
/// variant-selection policy (greedy | calibrating | epsilon[:E] |
/// forced:VARIANT).
pub fn parse_contexts(spec: &str) -> Result<Vec<CtxSpec>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let fields: Vec<&str> = part.splitn(3, ':').map(str::trim).collect();
        if fields.len() < 2 {
            bail!("bad context spec '{part}' (want name:count[:selector])");
        }
        let name = fields[0];
        let count: usize = fields[1]
            .parse()
            .with_context(|| format!("bad worker count in '{part}'"))?;
        if name.is_empty() || count == 0 {
            bail!("bad context spec '{part}' (empty name or zero workers)");
        }
        let selector = match fields.get(2) {
            Some(s) => Some(SelectorKind::parse(s).ok_or_else(|| {
                anyhow!("unknown selection policy '{s}' in '{part}' (want {VALID_SELECTORS})")
            })?),
            None => None,
        };
        let lower = name.to_ascii_lowercase();
        let arch = if lower.contains("gpu") || lower.contains("cuda") {
            Arch::Cuda
        } else {
            Arch::Cpu
        };
        out.push(CtxSpec {
            name: name.to_string(),
            count,
            arch,
            selector,
        });
    }
    Ok(out)
}

/// Server configuration (`compar serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    /// Context partitions; empty = one default context over ncpu/ncuda.
    pub contexts: Vec<CtxSpec>,
    pub sched: SchedPolicy,
    /// Default variant-selection policy for contexts without their own
    /// (`--selector`); `None` = inherit the environment-derived config
    /// (`COMPAR_SELECTOR`, with `STARPU_CALIBRATE` upgrading Greedy).
    pub selector: Option<SelectorKind>,
    /// Worker counts used when `contexts` is empty.
    pub ncpu: usize,
    pub ncuda: usize,
    /// Admission cap: requests admitted but not yet completed.
    pub max_inflight: usize,
    /// Base fuse window of the batcher. The *effective* window is
    /// snapshot-aware: it widens (up to 4x) while the runtime has a
    /// queue backlog — fusing more under pressure costs no extra
    /// latency when requests wait anyway — and shrinks to a quarter
    /// when the runtime is fully idle, where waiting is pure latency.
    pub batch_window: Duration,
    /// Max requests fused into one batch.
    pub max_batch: usize,
    /// Elastic worker scaling between scheduling contexts
    /// (`--autoscale`); `None` = static partitions.
    pub autoscale: Option<AutoscaleOptions>,
    /// Session transport: blocking thread-per-connection (default) or
    /// the readiness event loop (`--transport epoll`).
    pub transport: TransportKind,
    /// v9: selection-decision audit ring capacity (`--audit-cap`).
    /// 0 disables retention; the per-reason and total counters stay
    /// exact either way.
    pub audit_cap: usize,
    /// v9: live trace ring capacity in spans (`--trace-cap`).
    pub trace_cap: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            addr: "127.0.0.1:7199".into(),
            contexts: Vec::new(),
            sched: SchedPolicy::Dmda,
            selector: None,
            ncpu: 4,
            ncuda: 0,
            max_inflight: 64,
            batch_window: Duration::from_micros(500),
            max_batch: 16,
            autoscale: None,
            transport: TransportKind::Threads,
            audit_cap: crate::obs::DEFAULT_AUDIT_CAP,
            trace_cap: crate::obs::DEFAULT_TRACE_CAP,
        }
    }
}

/// Write deadline applied to every session socket: a peer that stops
/// reading cannot wedge a reply writer forever (symmetric with the
/// 100ms read timeout used for drain checks).
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

// -------------------------------------------------------- admission gate

/// Counting gate bounding admitted-but-incomplete requests; acquirers
/// block (backpressure) instead of failing.
struct Gate {
    max: usize,
    cur: Mutex<usize>,
    cv: Condvar,
}

impl Gate {
    fn new(max: usize) -> Gate {
        Gate {
            max: max.max(1),
            cur: Mutex::new(0),
            cv: Condvar::new(),
        }
    }

    fn acquire(&self) {
        let mut cur = self.cur.lock().unwrap();
        while *cur >= self.max {
            cur = self.cv.wait(cur).unwrap();
        }
        *cur += 1;
    }

    fn release(&self) {
        let mut cur = self.cur.lock().unwrap();
        *cur -= 1;
        self.cv.notify_all();
    }

    fn inflight(&self) -> usize {
        *self.cur.lock().unwrap()
    }
}

// ---------------------------------------------------------------- batching

/// A per-connection reply lane. Completion threads, stream workers and
/// the session itself all reply through it; the sink owns the session's
/// negotiated framing so every producer encodes consistently.
///
/// * `Blocking` — threaded transport: writes go straight to the socket
///   under a mutex (one coalesced buffered write per batch).
/// * `Queued` — epoll transport: frames are encoded into pooled buffers
///   and queued on the connection's [`Outbox`]; the event loop drains
///   them with vectored writes.
pub(crate) enum ReplySink {
    Blocking {
        stream: Mutex<TcpStream>,
        framing: Mutex<Framing>,
    },
    #[cfg(unix)]
    Queued {
        outbox: Arc<Outbox>,
        framing: Mutex<Framing>,
    },
}

pub(crate) type ReplyLane = Arc<ReplySink>;

impl ReplySink {
    fn blocking(stream: TcpStream) -> ReplyLane {
        Arc::new(ReplySink::Blocking {
            stream: Mutex::new(stream),
            framing: Mutex::new(Framing::Ndjson),
        })
    }

    /// Switch the wire framing (after a successful hello negotiation).
    fn set_framing(&self, f: Framing) {
        match self {
            ReplySink::Blocking { framing, .. } => *framing.lock().unwrap() = f,
            #[cfg(unix)]
            ReplySink::Queued { framing, .. } => *framing.lock().unwrap() = f,
        }
    }
}

fn send_line(lane: &ReplyLane, resp: &Response) -> bool {
    send_batch(lane, std::slice::from_ref(resp))
}

/// Encode a batch of responses and hand it to the session's sink as one
/// write. Returns false when the peer is gone: a failed reply write is
/// connection death, not something to swallow — log it and close the
/// socket so the reader side tears the session down promptly.
fn send_batch(lane: &ReplyLane, resps: &[Response]) -> bool {
    if resps.is_empty() {
        return true;
    }
    match &**lane {
        ReplySink::Blocking { stream, framing } => {
            let f = *framing.lock().unwrap();
            let mut buf = Vec::with_capacity(resps.len() * 128);
            for r in resps {
                encode_frame(f, &protocol::response_value(r), &mut buf);
            }
            let mut w = stream.lock().unwrap();
            match w.write_all(&buf).and_then(|_| w.flush()) {
                Ok(()) => true,
                Err(e) => {
                    eprintln!("serve: closing session, reply write failed: {e}");
                    let _ = w.shutdown(std::net::Shutdown::Both);
                    false
                }
            }
        }
        #[cfg(unix)]
        ReplySink::Queued { outbox, framing } => {
            let f = *framing.lock().unwrap();
            let mut buf = outbox.pool().take();
            for r in resps {
                encode_frame(f, &protocol::response_value(r), &mut buf);
            }
            outbox.send(buf)
        }
    }
}

struct Job {
    req: SubmitReq,
    ctx_id: CtxId,
    ctx_name: String,
    /// Name of the selection policy governing this request (reported in
    /// the result response).
    policy_name: String,
    /// Per-session selection policy to attach to the task specs (None =
    /// the context's policy, or a per-request `Forced` pin).
    selector: Option<Arc<dyn SelectionPolicy>>,
    /// v9: request trace id (minted at admission when the client sent
    /// none); stamped onto every task spec and echoed in the result.
    trace: u64,
    /// v9: admission instant — the end-to-end latency histogram
    /// observes `admitted.elapsed()` when the reply goes out.
    admitted: Instant,
    reply: ReplyLane,
}

#[derive(Default)]
struct BatchState {
    by_app: HashMap<String, Vec<Job>>,
    queued: usize,
    draining: bool,
}

/// Same-codelet request batching: jobs wait up to `window` so requests
/// for the same app fuse into one submission burst (amortizing scheduler
/// and perf-model lookups, and giving dmda a whole batch to spread over
/// the partition at once).
struct Batcher {
    state: Mutex<BatchState>,
    cv: Condvar,
    window: Duration,
    max_batch: usize,
}

impl Batcher {
    fn new(window: Duration, max_batch: usize) -> Batcher {
        Batcher {
            state: Mutex::new(BatchState::default()),
            cv: Condvar::new(),
            window,
            max_batch: max_batch.max(1),
        }
    }

    fn add(&self, job: Job) {
        let mut st = self.state.lock().unwrap();
        st.by_app.entry(job.req.app.clone()).or_default().push(job);
        st.queued += 1;
        drop(st);
        self.cv.notify_all();
    }

    fn drain(&self) {
        self.state.lock().unwrap().draining = true;
        self.cv.notify_all();
    }

    /// Dispatcher side: block for work, give same-app company the fuse
    /// window to arrive (unless a batch is already full), then take
    /// everything. The window is supplied by the caller *after* work
    /// exists — snapshot-aware batching reads the runtime's live queue
    /// depth / occupancy at that moment, not a stale pre-block value.
    /// Returns None when draining and empty.
    fn collect(&self, window: impl Fn() -> Duration) -> Option<Vec<(String, Vec<Job>)>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.queued == 0 {
                if st.draining {
                    return None;
                }
                st = self.cv.wait(st).unwrap();
                continue;
            }
            // accumulate: wait out the batch window unless a full batch
            // is already waiting or we're draining
            let full = st.by_app.values().any(|v| v.len() >= self.max_batch);
            if !full && !st.draining {
                let (g, _timeout) = self.cv.wait_timeout(st, window()).unwrap();
                st = g;
                if st.queued == 0 {
                    continue;
                }
            }
            st.queued = 0;
            return Some(std::mem::take(&mut st.by_app).into_iter().collect());
        }
    }
}

/// The snapshot-aware fuse window: scale the configured base by the
/// runtime's live pressure (the same `RuntimeSnapshot` features the
/// selection layer keys on). Idle runtime — nothing queued, nothing
/// executing — means waiting is pure added latency, so the window
/// shrinks to a quarter; a queue backlog means requests wait anyway, so
/// the window widens (up to 4x) and fuses more riders per batch.
fn adaptive_window(base: Duration, rt: &Runtime) -> Duration {
    let depth = rt.queued_tasks();
    if depth == 0 && rt.busy_workers() == 0 {
        return base / 4;
    }
    let per_worker = depth as f64 / rt.worker_count().max(1) as f64;
    base.mul_f64(1.0 + per_worker.min(3.0))
}

// ------------------------------------------------------------- the server

struct Shared {
    rt: Runtime,
    gate: Gate,
    batcher: Batcher,
    draining: AtomicBool,
    /// Set by a `shutdown` request; `serve_forever` waits on it.
    stop: Mutex<bool>,
    stop_cv: Condvar,
    sessions: Mutex<Vec<JoinHandle<()>>>,
    completions: Mutex<Vec<JoinHandle<()>>>,
    next_session: AtomicU64,
    requests_ok: AtomicU64,
    requests_err: AtomicU64,
    /// Stream sessions currently open (v6 stats gauge; streams also
    /// count into cluster placement through it).
    streams: AtomicU64,
    /// Graphs planned and released (v8; counts degraded-to-greedy
    /// submissions too — `planned_tasks` distinguishes them).
    plans: AtomicU64,
    /// Tasks released carrying a planned prefer-strength prior (v8).
    planned_tasks: AtomicU64,
    /// Same-app batches that fused more than one request (v9 monotonic
    /// total; `stats.batches_fused`).
    batches_fused: AtomicU64,
    /// Trace-id mint for requests arriving without one (v9). Starts at
    /// 1: trace 0 means "untraced" on every wire field and struct.
    next_trace: AtomicU64,
    /// Tasks completed per context id (results leave Metrics per-request,
    /// so the server keeps its own per-tenant counters).
    ctx_tasks: Vec<AtomicU64>,
    /// Per-context variant-selection histogram (context id -> variant
    /// name -> tasks executed with it).
    ctx_variants: Mutex<Vec<BTreeMap<String, u64>>>,
    /// Context routing table fixed at startup: name -> id.
    ctx_names: Vec<(String, CtxId)>,
    default_ctx: CtxId,
    /// Elastic-scaling state (v5 `autoscale_status`, hello SLO); set
    /// once right after the control loop starts.
    autoscale: Mutex<Option<Arc<AutoscaleShared>>>,
    /// The configured default SLO (`--slo-ms`), echoed in hello.
    slo_default: Option<f64>,
    started: Instant,
}

/// [`ScaleTarget`] adapter: the autoscale control loop samples and
/// reconfigures the server's runtime through its shared state.
struct ServeTarget(Arc<Shared>);

impl ScaleTarget for ServeTarget {
    fn loads(&self) -> Vec<CtxLoad> {
        self.0.rt.context_loads()
    }

    fn move_workers(&self, from: CtxId, to: CtxId, n: usize) -> Result<usize> {
        self.0.rt.move_workers(from, to, n)
    }
}

impl Shared {
    fn resolve_ctx(&self, name: Option<&str>) -> Result<(CtxId, String)> {
        match name {
            None => {
                let (n, id) = &self.ctx_names[self.default_ctx_index()];
                Ok((*id, n.clone()))
            }
            Some(n) => self
                .ctx_names
                .iter()
                .find(|(name, _)| name == n)
                .map(|(name, id)| (*id, name.clone()))
                .ok_or_else(|| {
                    anyhow!(
                        "unknown context '{n}' (have: {})",
                        self.ctx_names
                            .iter()
                            .map(|(n, _)| n.as_str())
                            .collect::<Vec<_>>()
                            .join(", ")
                    )
                }),
        }
    }

    fn default_ctx_index(&self) -> usize {
        self.ctx_names
            .iter()
            .position(|(_, id)| *id == self.default_ctx)
            .unwrap_or(0)
    }

    fn stats_snapshot(&self) -> StatsResp {
        let mut ctx_tasks = BTreeMap::new();
        for (name, id) in &self.ctx_names {
            ctx_tasks.insert(
                name.clone(),
                self.ctx_tasks
                    .get(*id)
                    .map(|a| a.load(Ordering::Relaxed))
                    .unwrap_or(0),
            );
        }
        let mut ctx_variants = BTreeMap::new();
        {
            let hists = self.ctx_variants.lock().unwrap();
            for (name, id) in &self.ctx_names {
                if let Some(h) = hists.get(*id) {
                    if !h.is_empty() {
                        ctx_variants.insert(name.clone(), h.clone());
                    }
                }
            }
        }
        // v6: the default context's *effective* SLO after live session
        // and stream declarations tightened it (0.0 when autoscaling is
        // off — no control loop, no target to report)
        let slo_ms = {
            let autoscale = self.autoscale.lock().unwrap();
            autoscale
                .as_ref()
                .and_then(|a| {
                    let (default_name, _) = &self.ctx_names[self.default_ctx_index()];
                    a.effective_slo(default_name, self.slo_default)
                })
                .unwrap_or(0.0)
        };
        StatsResp {
            uptime: self.started.elapsed().as_secs_f64(),
            requests_ok: self.requests_ok.load(Ordering::Relaxed),
            requests_err: self.requests_err.load(Ordering::Relaxed),
            inflight: self.gate.inflight() as u64,
            tasks_executed: self
                .rt
                .metrics()
                .tasks_executed
                .load(Ordering::Relaxed) as u64,
            // v4: the runtime-snapshot features (what the selection
            // layer's RuntimeSnapshot sees, aggregated server-wide)
            queue_depth: self.rt.queued_tasks() as u64,
            busy_workers: self.rt.busy_workers() as u64,
            total_workers: self.rt.worker_count() as u64,
            sessions: self.rt.tenants() as u64,
            ctx_tasks,
            ctx_variants,
            slo_ms,
            streams: self.streams.load(Ordering::Relaxed),
            plans: self.plans.load(Ordering::Relaxed),
            planned_tasks: self.planned_tasks.load(Ordering::Relaxed),
            // v9: monotonic totals — unlike the gauges above these
            // never reset, so a scraper can difference them over time
            tasks_completed: self
                .rt
                .metrics()
                .tasks_executed
                .load(Ordering::Relaxed) as u64,
            bytes_transferred: self.rt.metrics().bytes_transferred.load(Ordering::Relaxed),
            batches_fused: self.batches_fused.load(Ordering::Relaxed),
            decisions: self.rt.obs().decisions(),
        }
    }

    /// Mirror the runtime's and the server's own aggregates into the
    /// metrics registry at scrape time. The sources of truth stay where
    /// they are (taskrt atomics, server counters) — the registry is the
    /// export surface, so the hot path never double-books. Counters are
    /// mirrored from monotonic sources only, preserving monotonicity
    /// for scrapers that difference them.
    fn mirror_metrics(&self) {
        let obs = self.rt.obs();
        let reg = &obs.registry;
        let m = self.rt.metrics();
        reg.counter("taskrt_tasks_completed_total").store(
            m.tasks_executed.load(Ordering::Relaxed) as u64,
            Ordering::Relaxed,
        );
        reg.counter("taskrt_tasks_failed_total").store(
            m.tasks_failed.load(Ordering::Relaxed) as u64,
            Ordering::Relaxed,
        );
        reg.counter("taskrt_bytes_transferred_total").store(
            m.bytes_transferred.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
        reg.counter("serve_requests_ok_total")
            .store(self.requests_ok.load(Ordering::Relaxed), Ordering::Relaxed);
        reg.counter("serve_requests_err_total")
            .store(self.requests_err.load(Ordering::Relaxed), Ordering::Relaxed);
        reg.counter("serve_batches_fused_total")
            .store(self.batches_fused.load(Ordering::Relaxed), Ordering::Relaxed);
        reg.counter("serve_plans_total")
            .store(self.plans.load(Ordering::Relaxed), Ordering::Relaxed);
        reg.counter("serve_planned_tasks_total")
            .store(self.planned_tasks.load(Ordering::Relaxed), Ordering::Relaxed);
        reg.gauge("serve_inflight")
            .store(self.gate.inflight() as i64, Ordering::Relaxed);
        reg.gauge("serve_streams")
            .store(self.streams.load(Ordering::Relaxed) as i64, Ordering::Relaxed);
        reg.gauge("serve_sessions")
            .store(self.rt.tenants() as i64, Ordering::Relaxed);
        reg.gauge("taskrt_queue_depth")
            .store(self.rt.queued_tasks() as i64, Ordering::Relaxed);
        reg.gauge("taskrt_busy_workers")
            .store(self.rt.busy_workers() as i64, Ordering::Relaxed);
        reg.gauge("taskrt_total_workers")
            .store(self.rt.worker_count() as i64, Ordering::Relaxed);
        // elastic-scaling lifetime counters (when the control loop runs)
        if let Some(a) = self.autoscale.lock().unwrap().as_ref() {
            let st = a.status();
            reg.counter("autoscale_moves_total")
                .store(st.moves, Ordering::Relaxed);
            reg.counter("autoscale_moved_workers_total")
                .store(st.moved_workers, Ordering::Relaxed);
        }
    }
}

/// The multi-tenant component service. `start` binds and returns
/// immediately; `serve_forever` blocks until a client sends `shutdown`;
/// `shutdown` drains gracefully.
pub struct Server {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    dispatcher: Option<JoinHandle<()>>,
    /// The elastic control loop (owns its thread; stopped on shutdown).
    autoscaler: Option<Autoscaler>,
}

impl Server {
    pub fn start(opts: ServeOptions) -> Result<Server> {
        // worker counts follow the context partitioning when given
        let (ncpu, ncuda) = if opts.contexts.is_empty() {
            (opts.ncpu, opts.ncuda)
        } else {
            (
                opts.contexts
                    .iter()
                    .filter(|c| c.arch == Arch::Cpu)
                    .map(|c| c.count)
                    .sum(),
                opts.contexts
                    .iter()
                    .filter(|c| c.arch == Arch::Cuda)
                    .map(|c| c.count)
                    .sum(),
            )
        };
        let mut cfg = Config::from_env();
        cfg.ncpu = ncpu;
        cfg.ncuda = ncuda;
        cfg.sched = opts.sched;
        // --selector overrides the env-derived default; otherwise the
        // env config (COMPAR_SELECTOR / STARPU_CALIBRATE) stands
        if let Some(sel) = &opts.selector {
            cfg.selector = sel.clone();
        }
        let default_selector = cfg.effective_selector();
        let manifest = Manifest::load(&crate::runtime::manifest::default_dir())
            .ok()
            .map(Arc::new);
        let rt = Runtime::new(cfg, manifest)?;
        // v9: size the observability rings before any traffic arrives
        rt.obs().audit.set_capacity(opts.audit_cap);
        rt.obs().trace.set_capacity(opts.trace_cap);

        // carve the requested partitions; cpu workers occupy global ids
        // [0, ncpu), cuda workers [ncpu, ncpu+ncuda) (paper_topology order)
        let mut ctx_names: Vec<(String, CtxId)> = vec![("default".into(), 0)];
        let mut default_ctx = 0;
        if !opts.contexts.is_empty() {
            let mut next_cpu = 0usize;
            let mut next_cuda = ncpu;
            for spec in &opts.contexts {
                let ids: Vec<usize> = match spec.arch {
                    Arch::Cpu => {
                        let ids = (next_cpu..next_cpu + spec.count).collect();
                        next_cpu += spec.count;
                        ids
                    }
                    Arch::Cuda => {
                        let ids = (next_cuda..next_cuda + spec.count).collect();
                        next_cuda += spec.count;
                        ids
                    }
                };
                let selector = spec
                    .selector
                    .clone()
                    .unwrap_or_else(|| default_selector.clone());
                let id = rt.create_context_with(&spec.name, &ids, opts.sched, selector)?;
                ctx_names.push((spec.name.clone(), id));
            }
            // all workers moved out of the default context: route
            // ctx-less requests to the first named partition instead
            default_ctx = ctx_names[1].1;
        }

        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let n_slots = ctx_names.len().max(rt.contexts().len());
        let shared = Arc::new(Shared {
            ctx_tasks: (0..n_slots).map(|_| AtomicU64::new(0)).collect(),
            ctx_variants: Mutex::new(vec![BTreeMap::new(); n_slots]),
            rt,
            gate: Gate::new(opts.max_inflight),
            batcher: Batcher::new(opts.batch_window, opts.max_batch),
            draining: AtomicBool::new(false),
            stop: Mutex::new(false),
            stop_cv: Condvar::new(),
            sessions: Mutex::new(Vec::new()),
            completions: Mutex::new(Vec::new()),
            next_session: AtomicU64::new(1),
            requests_ok: AtomicU64::new(0),
            requests_err: AtomicU64::new(0),
            streams: AtomicU64::new(0),
            plans: AtomicU64::new(0),
            planned_tasks: AtomicU64::new(0),
            batches_fused: AtomicU64::new(0),
            next_trace: AtomicU64::new(1),
            ctx_names,
            default_ctx,
            autoscale: Mutex::new(None),
            slo_default: opts.autoscale.as_ref().and_then(|a| a.slo_ms),
            started: Instant::now(),
        });

        // the elastic control loop, resizing scheduling contexts live
        let autoscaler = opts.autoscale.clone().map(|aopts| {
            let scaler = Autoscaler::start(Arc::new(ServeTarget(shared.clone())), aopts);
            *shared.autoscale.lock().unwrap() = Some(scaler.shared());
            scaler
        });

        // the accept thread doubles as the whole transport on the epoll
        // path: instead of spawning a thread per connection it runs the
        // readiness event loop, multiplexing every session itself
        let transport = opts.transport;
        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || match transport {
                    TransportKind::Threads => accept_loop(shared, listener),
                    TransportKind::Epoll => mux_transport(shared, listener),
                })
                .expect("spawning accept thread")
        };
        let dispatcher = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("serve-dispatch".into())
                .spawn(move || dispatch_loop(shared))
                .expect("spawning dispatcher thread")
        };

        Ok(Server {
            local_addr,
            shared,
            accept: Some(accept),
            dispatcher: Some(dispatcher),
            autoscaler,
        })
    }

    /// The actual bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The runtime's performance-model store (gossip tests / tooling):
    /// local observations plus the remote overlay installed by
    /// `perf_push`.
    pub fn perf_models(&self) -> Arc<crate::taskrt::PerfModels> {
        self.shared.rt.perf_models().clone()
    }

    /// Register an extra codelet on the server's runtime *before*
    /// traffic arrives, shadowing the stock app codelet of the same
    /// name. Streaming benches and tests use this to install a native
    /// device-emulating variant ([`crate::stream::emulated_device_sort`])
    /// where the real CUDA variant would need a compiled artifact
    /// manifest and an XLA service.
    pub fn register_codelet(&self, c: Codelet) -> Arc<Codelet> {
        self.shared.rt.register_codelet(c)
    }

    /// Context partitions (name -> worker ids), for tooling and tests.
    pub fn context_table(&self) -> Vec<(String, Vec<usize>)> {
        let infos = self.shared.rt.contexts();
        self.shared
            .ctx_names
            .iter()
            .map(|(name, id)| (name.clone(), infos[*id].workers.clone()))
            .collect()
    }

    /// Block until a client sends a `shutdown` request, then drain.
    pub fn serve_forever(self) -> Result<StatsResp> {
        {
            let mut stop = self.shared.stop.lock().unwrap();
            while !*stop {
                stop = self.shared.stop_cv.wait(stop).unwrap();
            }
        }
        self.shutdown()
    }

    /// Live elastic-scaling status (None when autoscaling is off).
    pub fn autoscale_status(&self) -> Option<crate::autoscale::AutoscaleStatus> {
        self.autoscaler.as_ref().map(|a| a.status())
    }

    /// Graceful drain: stop accepting, let sessions finish, flush the
    /// batcher, wait for every admitted request to complete.
    pub fn shutdown(mut self) -> Result<StatsResp> {
        // stop the control loop first: a drain must not race worker
        // migrations
        if let Some(a) = self.autoscaler.take() {
            a.stop();
        }
        let shared = &self.shared;
        shared.draining.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        // sessions observe `draining` within one read timeout; join them
        // *before* draining the batcher so a session blocked on the
        // admission gate can still enqueue (its job will be flushed).
        loop {
            let handles: Vec<JoinHandle<()>> =
                std::mem::take(&mut *shared.sessions.lock().unwrap());
            if handles.is_empty() {
                break;
            }
            for h in handles {
                let _ = h.join();
            }
        }
        shared.batcher.drain();
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
        // dispatcher exited => no new completion threads can appear
        let completions: Vec<JoinHandle<()>> =
            std::mem::take(&mut *shared.completions.lock().unwrap());
        for c in completions {
            let _ = c.join();
        }
        debug_assert_eq!(shared.gate.inflight(), 0, "drain left requests behind");
        // belt-and-braces: any stray tasks (there should be none)
        let _ = shared.rt.wait_all();
        Ok(shared.stats_snapshot())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.batcher.drain();
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
    }
}

// ------------------------------------------------------------ accept loop

/// `--transport epoll` entry point: the readiness event loop (unix), or
/// a loud fallback to the threaded path elsewhere.
#[cfg(unix)]
fn mux_transport(shared: Arc<Shared>, listener: TcpListener) {
    mux::event_loop(shared, listener);
}

#[cfg(not(unix))]
fn mux_transport(shared: Arc<Shared>, listener: TcpListener) {
    eprintln!("serve: epoll transport needs a unix platform; using threads");
    accept_loop(shared, listener);
}

fn accept_loop(shared: Arc<Shared>, listener: TcpListener) {
    while !shared.draining.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let sid = shared.next_session.fetch_add(1, Ordering::Relaxed);
                let shared2 = shared.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("serve-session-{sid}"))
                    .spawn(move || session_loop(shared2, stream, sid))
                    .expect("spawning session thread");
                let mut sessions = shared.sessions.lock().unwrap();
                // reap finished sessions so the list stays bounded under
                // connection churn (health probes and gossip open a
                // short-lived session every round)
                crate::util::threads::reap_finished(&mut sessions);
                sessions.push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

// ----------------------------------------------------------- session loop

/// Per-session mutable state (the session thread owns it).
#[derive(Default)]
struct SessionState {
    /// Selection policy chosen in the hello handshake: one live
    /// instance shared by every submit on this session, so stateful
    /// policies (epsilon-greedy exploration counters) learn across the
    /// session's requests.
    policy: Option<(String, Arc<dyn SelectionPolicy>)>,
    /// Latency SLO declared in the hello (v5): tightens the autoscale
    /// target of every context this session submits to.
    slo_ms: Option<f64>,
    /// Contexts this session already declared its SLO for — the
    /// registration is once per (session, context), so the submit hot
    /// path normally touches no autoscale lock at all.
    slo_declared: Vec<CtxId>,
    /// Open stream sessions (v6), keyed by the client-chosen stream id.
    streams: HashMap<u64, StreamHandle>,
    /// Wire framing negotiated in hello (v7); the transport mirrors it
    /// into its frame decoder after each dispatched request.
    framing: Framing,
}

fn session_loop(shared: Arc<Shared>, stream: TcpStream, sid: u64) {
    let _ = stream.set_nodelay(true);
    // periodic timeout so the session observes `draining` while idle
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    // symmetric write deadline: a peer that stops reading cannot wedge
    // completion threads inside the reply-lane mutex
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let reply: ReplyLane = match stream.try_clone() {
        Ok(w) => ReplySink::blocking(w),
        Err(_) => return,
    };
    // count the session into the runtime's co-tenant gauge: selection
    // snapshots (and v4 stats) see how many clients share the machine
    shared.rt.tenant_started();
    let mut stream = stream;
    let mut dec = FrameDecoder::new(Framing::Ndjson);
    let mut sess = SessionState::default();
    'session: loop {
        // surface every frame already buffered before touching the socket
        loop {
            match dec.next() {
                Ok(Some(v)) => {
                    let keep = handle_frame(&shared, &reply, &v, sid, &mut sess);
                    // hello may have renegotiated the wire framing
                    if sess.framing != dec.framing() {
                        dec.set_framing(sess.framing);
                    }
                    // also break on drain here: a chatty client whose
                    // reads never time out must not hold the session
                    // (and thereby Server::shutdown's join) open forever
                    if !keep || shared.draining.load(Ordering::SeqCst) {
                        break 'session;
                    }
                }
                Ok(None) => break,
                Err(e) => {
                    // framing desync: the stream is unrecoverable
                    send_line(
                        &reply,
                        &Response::Error {
                            id: None,
                            error: format!("{e:#}"),
                        },
                    );
                    break 'session;
                }
            }
        }
        match dec.fill_from(&mut stream) {
            Ok(0) => break, // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // partial data stays buffered in the decoder; check drain
                if shared.draining.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    // streams the client left open are flushed and closed with the
    // session (their persistent window state must not outlive it)
    for (_, h) in std::mem::take(&mut sess.streams) {
        close_stream(&shared, h);
    }
    // the session's SLO declarations die with it (v5 semantics)
    if let Some(a) = shared.autoscale.lock().unwrap().as_ref() {
        a.release_session(sid);
    }
    shared.rt.tenant_finished();
}

/// Decode one framed request value and dispatch it; returns false when
/// the session should close. Pure over the decoded value — both the
/// threaded path and the event loop call this.
fn handle_frame(
    shared: &Arc<Shared>,
    reply: &ReplyLane,
    value: &Json,
    sid: u64,
    sess: &mut SessionState,
) -> bool {
    let req = match protocol::request_from_value(value) {
        Ok(r) => r,
        Err(e) => {
            send_line(
                reply,
                &Response::Error {
                    id: None,
                    error: format!("{e:#}"),
                },
            );
            return true;
        }
    };
    dispatch_request(shared, reply, req, sid, sess)
}

/// Handle one decoded request; returns false when the session should
/// close.
fn dispatch_request(
    shared: &Arc<Shared>,
    reply: &ReplyLane,
    req: Request,
    sid: u64,
    sess: &mut SessionState,
) -> bool {
    match req {
        Request::Hello {
            client: _,
            policy,
            slo_ms,
            framing,
        } => {
            // v7: negotiate the session's wire framing before anything
            // else can fail — the hello *response* still goes out in
            // the current (pre-switch) framing, everything after it in
            // the accepted one.
            let accepted = match framing.as_deref().map(Framing::parse) {
                None => None,
                Some(Ok(f)) => Some(f),
                Some(Err(e)) => {
                    send_line(
                        reply,
                        &Response::Error {
                            id: None,
                            error: format!("{e:#}"),
                        },
                    );
                    return true;
                }
            };
            if let Some(p) = policy {
                match SelectorKind::parse(&p) {
                    Some(kind) => {
                        sess.policy = Some((kind.name(), kind.build(sid)));
                    }
                    None => {
                        send_line(
                            reply,
                            &Response::Error {
                                id: None,
                                error: format!(
                                    "unknown selection policy '{p}' (want {VALID_SELECTORS})"
                                ),
                            },
                        );
                        return true;
                    }
                }
            }
            // v5: a declared session SLO tightens the autoscaler's
            // target for the contexts the session actually submits to
            // (registered per submit below — declaring here must not
            // skew contexts the session never uses). The response
            // echoes the target the session would see on the default
            // context: the current effective one, tightened by its own
            // declaration.
            sess.slo_ms = slo_ms;
            // a re-declaration replaces the session's earlier target:
            // force per-context re-registration on the next submits
            sess.slo_declared.clear();
            let effective = {
                let autoscale = shared.autoscale.lock().unwrap();
                autoscale.as_ref().and_then(|a| {
                    let (default_name, _) = &shared.ctx_names[shared.default_ctx_index()];
                    let eff = a.effective_slo(default_name, shared.slo_default);
                    match (eff, slo_ms) {
                        (Some(x), Some(y)) => Some(x.min(y)),
                        (x, y) => x.or(y),
                    }
                })
            };
            send_line(
                reply,
                &Response::Hello {
                    session: sid,
                    version: PROTOCOL_VERSION,
                    slo_ms: effective,
                    // echo what was accepted; absent = ndjson, so older
                    // clients that never asked see no new field
                    framing: accepted.map(|f| f.name().to_string()),
                },
            );
            // switch *after* the hello reply is encoded: the handshake
            // itself is always readable in the session's prior framing
            if let Some(f) = accepted {
                sess.framing = f;
                reply.set_framing(f);
            }
            true
        }
        Request::Stats => {
            send_line(reply, &Response::Stats(shared.stats_snapshot()));
            true
        }
        Request::Metrics { format } => {
            // v9: one registry scrape — mirror the runtime/server
            // aggregates in first so the registry view is complete
            let text = match format.as_deref() {
                None | Some("json") => None,
                Some("prometheus") | Some("text") => Some(()),
                Some(other) => {
                    send_line(
                        reply,
                        &Response::Error {
                            id: None,
                            error: format!(
                                "unknown metrics format '{other}' (want json | prometheus)"
                            ),
                        },
                    );
                    return true;
                }
            };
            shared.mirror_metrics();
            let obs = shared.rt.obs();
            send_line(
                reply,
                &Response::Metrics(MetricsResp {
                    metrics: obs.metrics_json(),
                    text: text.map(|()| obs.render_prometheus()),
                }),
            );
            true
        }
        Request::Decisions { limit, codelet } => {
            // v9: newest slice of the selection-decision audit ring
            let obs = shared.rt.obs();
            let limit = limit.map(|l| l.min(4096) as usize).unwrap_or(64);
            let recs = obs.audit.recent(limit, codelet.as_deref().unwrap_or(""));
            send_line(
                reply,
                &Response::Decisions(DecisionsResp {
                    total: obs.audit.recorded(),
                    dropped: obs.audit.dropped(),
                    evicted: obs.audit.evicted(),
                    decisions: Json::Arr(recs.iter().map(|r| r.to_json()).collect()),
                }),
            );
            true
        }
        Request::DumpTrace => {
            // v9: flush the live trace ring as Trace Event Format
            let obs = shared.rt.obs();
            let events = obs.trace.len() as u64;
            send_line(
                reply,
                &Response::DumpTrace(TraceResp {
                    events,
                    trace: obs.trace.chrome_json(0),
                }),
            );
            true
        }
        Request::Contexts => {
            let contexts = shared
                .rt
                .contexts()
                .into_iter()
                .map(|c| CtxDesc {
                    id: c.id,
                    name: c.name,
                    policy: c.policy.name().to_string(),
                    selector: c.selector,
                    workers: c.workers,
                    queued: c.queued,
                })
                .collect();
            send_line(reply, &Response::Contexts { contexts });
            true
        }
        Request::AutoscaleStatus => {
            let resp = match shared.autoscale.lock().unwrap().as_ref() {
                Some(a) => {
                    let st = a.status();
                    AutoscaleResp {
                        enabled: st.enabled,
                        policy: st.policy,
                        moves: st.moves,
                        moved_workers: st.moved_workers,
                        last_action: st.last_action,
                        contexts: st
                            .contexts
                            .iter()
                            .map(|c| AutoscaleCtxDesc {
                                name: c.name.clone(),
                                workers: c.workers as u64,
                                home: c.home as u64,
                                min: c.min as u64,
                                max: c.max as u64,
                                queue_depth: c.queue_depth as u64,
                                slo_ms: c.slo_ms,
                            })
                            .collect(),
                        ..AutoscaleResp::default()
                    }
                }
                None => AutoscaleResp::default(),
            };
            send_line(reply, &Response::Autoscale(resp));
            true
        }
        Request::PerfPull => {
            send_line(
                reply,
                &Response::PerfModels {
                    models: shared.rt.perf_models().to_json(),
                    // v8: banded selection summaries ride the same pull,
                    // so peer shards plan graphs with this shard's
                    // interference evidence
                    bands: shared.rt.export_selection_bands(),
                },
            );
            true
        }
        Request::PerfPush { models, bands } => {
            let mut merged = shared.rt.perf_models().set_remote_json(&models) as u64;
            if let Some(b) = &bands {
                merged += shared.rt.import_selection_bands(b) as u64;
            }
            send_line(reply, &Response::PerfAck { merged });
            true
        }
        Request::Shards | Request::DrainShard { .. } => {
            send_line(
                reply,
                &Response::Error {
                    id: None,
                    error: "router-level operation (this is a shard server; \
                            send it to `compar route`)"
                        .into(),
                },
            );
            true
        }
        Request::Shutdown => {
            send_line(reply, &Response::Shutdown);
            let mut stop = shared.stop.lock().unwrap();
            *stop = true;
            shared.stop_cv.notify_all();
            true
        }
        Request::Quit => {
            send_line(reply, &Response::Bye);
            false
        }
        Request::StreamOpen(req) => {
            stream_open(shared, reply, req, sid, sess);
            true
        }
        Request::StreamChunk { stream, seq, seed } => {
            stream_chunk(shared, reply, stream, seq, seed, sess);
            true
        }
        Request::StreamClose { stream } => {
            match sess.streams.remove(&stream) {
                Some(h) => close_stream(shared, h),
                None => {
                    send_line(
                        reply,
                        &Response::Error {
                            id: None,
                            error: format!("unknown stream {stream}"),
                        },
                    );
                }
            }
            true
        }
        Request::SubmitGraph(req) => {
            submit_graph_request(shared, reply, req, sid, sess);
            true
        }
        Request::Submit(mut req) => {
            let id = req.id;
            if shared.draining.load(Ordering::SeqCst) {
                send_line(
                    reply,
                    &Response::Error {
                        id: Some(id),
                        error: "server is draining".into(),
                    },
                );
                return true;
            }
            let (ctx_id, ctx_name) = match shared.resolve_ctx(req.ctx.as_deref()) {
                Ok(x) => x,
                Err(e) => {
                    shared.requests_err.fetch_add(1, Ordering::Relaxed);
                    send_line(
                        reply,
                        &Response::Error {
                            id: Some(id),
                            error: format!("{e:#}"),
                        },
                    );
                    return true;
                }
            };
            // the session's declared SLO follows its submits: the
            // tightest *live* declared target per context wins, and the
            // declaration dies with the session (v5 semantics). Once
            // per (session, context), so steady-state submits skip the
            // autoscale locks entirely.
            if let Some(ms) = sess.slo_ms {
                if !sess.slo_declared.contains(&ctx_id) {
                    if let Some(a) = shared.autoscale.lock().unwrap().as_ref() {
                        a.tighten_slo(&ctx_name, sid, ms);
                    }
                    sess.slo_declared.push(ctx_id);
                }
            }
            // which policy governs the request: a pinned variant wins,
            // then the session policy, then the context's own
            let policy_name = if let Some(v) = &req.variant {
                format!("forced:{v}")
            } else if let Some((name, _)) = &sess.policy {
                name.clone()
            } else {
                shared
                    .rt
                    .context_selector_name(ctx_id)
                    .unwrap_or_else(|| "greedy".into())
            };
            let selector = sess.policy.as_ref().map(|(_, s)| s.clone());
            // v9: mint the request's trace id when the client (or an
            // upstream router) sent none — every admitted request is
            // traceable end to end
            if req.trace == 0 {
                req.trace = shared.next_trace.fetch_add(1, Ordering::Relaxed);
            }
            // admission control: block (backpressure) until capacity;
            // the wait is a request-scoped span on the session's lane
            let obs = shared.rt.obs();
            let t_gate = obs.now_secs();
            shared.gate.acquire();
            obs.trace.push(SpanEvent {
                name: format!("admit:{}", req.app),
                cat: "serve",
                lane: sid,
                lane_name: format!("session{sid}"),
                trace: req.trace,
                t_start: t_gate,
                t_end: obs.now_secs(),
            });
            shared.batcher.add(Job {
                trace: req.trace,
                admitted: Instant::now(),
                req,
                ctx_id,
                ctx_name,
                policy_name,
                selector,
                reply: reply.clone(),
            });
            true
        }
    }
}

// --------------------------------------------------------- graph planning

/// Admit one `submit_graph` request (v8): validate the context and
/// mode on the session thread, then hand planning + release + wait to
/// a dedicated thread — a whole-graph wait must not block the session
/// loop any more than a batch wait may block the dispatcher.
fn submit_graph_request(
    shared: &Arc<Shared>,
    reply: &ReplyLane,
    mut req: SubmitGraphReq,
    sid: u64,
    sess: &mut SessionState,
) {
    let id = req.id;
    let fail = |shared: &Arc<Shared>, e: String| {
        shared.requests_err.fetch_add(1, Ordering::Relaxed);
        send_line(reply, &Response::Error { id: Some(id), error: e });
    };
    if shared.draining.load(Ordering::SeqCst) {
        return fail(shared, "server is draining".into());
    }
    let (ctx_id, ctx_name) = match shared.resolve_ctx(req.ctx.as_deref()) {
        Ok(x) => x,
        Err(e) => return fail(shared, format!("{e:#}")),
    };
    // `mode` forces the baseline: "greedy" skips the lookahead pass
    // entirely (bench baselines, degradation tests); default = planned
    let force_greedy = match req.mode.as_deref() {
        None | Some("planned") => false,
        Some("greedy") => true,
        Some(other) => {
            return fail(
                shared,
                format!("unknown graph mode '{other}' (want planned | greedy)"),
            )
        }
    };
    // the session's declared SLO follows graph submits exactly like
    // scalar submits (v5 semantics)
    if let Some(ms) = sess.slo_ms {
        if !sess.slo_declared.contains(&ctx_id) {
            if let Some(a) = shared.autoscale.lock().unwrap().as_ref() {
                a.tighten_slo(&ctx_name, sid, ms);
            }
            sess.slo_declared.push(ctx_id);
        }
    }
    let base_selector = sess.policy.as_ref().map(|(_, s)| s.clone());
    // v9: graphs are traced like scalar submits — one id for the DAG
    if req.trace == 0 {
        req.trace = shared.next_trace.fetch_add(1, Ordering::Relaxed);
    }
    // one gate slot per graph: the whole DAG is one admitted request
    shared.gate.acquire();
    let admitted = Instant::now();
    let shared2 = shared.clone();
    let reply = reply.clone();
    let handle = std::thread::Builder::new()
        .name("serve-graph".into())
        .spawn(move || {
            let resp = match run_graph(&shared2, req, ctx_id, &ctx_name, base_selector, force_greedy)
            {
                Ok(r) => {
                    shared2.requests_ok.fetch_add(1, Ordering::Relaxed);
                    // end-to-end latency: admission -> reply (success
                    // only, so count reconciles with requests_ok)
                    shared2
                        .rt
                        .obs()
                        .e2e_seconds()
                        .observe(admitted.elapsed().as_secs_f64());
                    Response::GraphDone(r)
                }
                Err(e) => {
                    shared2.requests_err.fetch_add(1, Ordering::Relaxed);
                    Response::Error {
                        id: Some(id),
                        error: format!("{e:#}"),
                    }
                }
            };
            send_line(&reply, &resp);
            shared2.gate.release();
        })
        .expect("spawning graph thread");
    shared.completions.lock().unwrap().push(handle);
}

/// Build the [`GraphSpec`], plan + release it, wait out every node and
/// assemble the per-node report. Consumer nodes of the same app and
/// size share their producer's handles, so a dependency edge is a real
/// data dependency through the registry — exactly the bytes the planner
/// prices (and elides when both ends land on one arch).
fn run_graph(
    shared: &Arc<Shared>,
    req: SubmitGraphReq,
    ctx_id: CtxId,
    ctx_name: &str,
    base_selector: Option<Arc<dyn SelectionPolicy>>,
    force_greedy: bool,
) -> Result<GraphDoneResp> {
    let rt = &shared.rt;
    let t0 = Instant::now();
    let mut spec = GraphSpec::new();
    spec.trace = req.trace;
    let mut index: HashMap<String, usize> = HashMap::new();
    let mut owned: Vec<HandleId> = Vec::new();
    let mut node_handles: Vec<Vec<HandleId>> = Vec::new();
    let mut node_keys: Vec<(String, usize)> = Vec::new();
    let built = (|| -> Result<()> {
        for (i, n) in req.nodes.iter().enumerate() {
            let cl_name = apps::app_codelet_name(&n.app).to_string();
            let cl = match rt.codelet(&cl_name) {
                Some(c) => c,
                None => rt.register_codelet(apps::codelet(&n.app)?),
            };
            let mut deps = Vec::with_capacity(n.deps.len());
            for d in &n.deps {
                let j = *index.get(d).ok_or_else(|| {
                    anyhow!("node '{}' depends on unknown node '{d}' (deps must name earlier nodes)", n.name)
                })?;
                deps.push(j);
            }
            // chain through the first compatible producer's handles
            let handles = match deps
                .iter()
                .copied()
                .find(|&j| node_keys[j] == (n.app.clone(), n.size))
            {
                Some(j) => node_handles[j].clone(),
                None => {
                    let seed = req.id ^ (i as u64).wrapping_mul(0x9e3779b97f4a7c15);
                    let inst = apps::prepare(rt, &n.app, n.size, seed)?;
                    owned.extend(inst.owned_handles());
                    inst.handles
                }
            };
            // a pinned variant must exist; a typo is a protocol error
            if let Some(v) = &n.variant {
                if cl.impl_by_name(v).is_none() {
                    let known: Vec<&str> = cl.impls.iter().map(|i| i.name.as_str()).collect();
                    bail!(
                        "node '{}': unknown variant '{v}' for app '{}' (registered: {})",
                        n.name,
                        n.app,
                        known.join(", ")
                    );
                }
            }
            spec.add_node(&n.name, cl, handles.clone(), n.size, &deps)?;
            if let Some(v) = &n.variant {
                spec.pin_last(v);
            }
            index.insert(n.name.clone(), i);
            node_handles.push(handles);
            node_keys.push((n.app.clone(), n.size));
        }
        Ok(())
    })();
    let run = match built.and_then(|()| rt.submit_graph(&spec, ctx_id, base_selector, force_greedy))
    {
        Ok(r) => r,
        Err(e) => {
            for h in &owned {
                let _ = rt.unregister_data(*h);
            }
            return Err(e);
        }
    };
    let waited = rt.wait_tasks(&run.tasks);
    let results = rt.metrics().take_results_for(&run.tasks);
    if let Some(c) = shared.ctx_tasks.get(ctx_id) {
        c.fetch_add(results.len() as u64, Ordering::Relaxed);
    }
    {
        let mut hists = shared.ctx_variants.lock().unwrap();
        if let Some(h) = hists.get_mut(ctx_id) {
            for r in &results {
                *h.entry(r.variant.clone()).or_insert(0) += 1;
            }
        }
    }
    rt.reap_tasks(&run.tasks);
    for h in &owned {
        let _ = rt.unregister_data(*h);
    }
    waited?;
    let plan = &run.plan;
    shared.plans.fetch_add(1, Ordering::Relaxed);
    if plan.mode == PlanMode::Planned {
        shared
            .planned_tasks
            .fetch_add(run.tasks.len() as u64, Ordering::Relaxed);
    }
    let mut nodes = Vec::with_capacity(plan.assignments.len());
    for (a, tid) in plan.assignments.iter().zip(&run.tasks) {
        let r = results
            .iter()
            .find(|r| r.task == *tid)
            .ok_or_else(|| anyhow!("graph node '{}' finished without a result", a.name))?;
        nodes.push(GraphNodeReport {
            name: a.name.clone(),
            // the variant actually executed — comparing it against the
            // plan's prefer-strength choice is the whole observability
            // point of the per-node report
            variant: r.variant.clone(),
            arch: match a.arch {
                Arch::Cpu => "cpu".into(),
                Arch::Cuda => "cuda".into(),
            },
            planned: plan.mode == PlanMode::Planned,
            est: a.est,
            modeled: r.modeled_total(),
            wall: r.wall,
            elided: a.elided,
        });
    }
    Ok(GraphDoneResp {
        id: req.id,
        ctx: ctx_name.to_string(),
        mode: plan.mode.name().to_string(),
        makespan: plan.makespan,
        wall: t0.elapsed().as_secs_f64(),
        elided_transfers: plan.elided_transfers as u64,
        nodes,
    })
}

// -------------------------------------------------------------- streaming

/// One open stream, owned by its session thread. Submission state
/// (windower, persistent window accumulator) lives here; completion
/// state (credit controller, backlog model, latency track) lives in the
/// stream's worker thread; the two halves meet in [`StreamShared`].
struct StreamHandle {
    spec: StreamSpec,
    ctx_id: CtxId,
    codelet: Arc<Codelet>,
    /// v9: the stream's trace id — every chunk-stage task carries it,
    /// so one stream's spans correlate in the live trace ring.
    trace: u64,
    /// Per-session selection policy (None = the context's policy).
    selector: Option<Arc<dyn SelectionPolicy>>,
    state: Arc<StreamShared>,
    windower: Option<Windower>,
    /// Persistent window state: an app instance whose handles stay
    /// registered in the `DataRegistry` for the stream's whole life, so
    /// residency pricing sees the windowed stage as resident data
    /// across firings.
    acc: Option<apps::Instance>,
    tx: mpsc::Sender<StreamWork>,
    worker: Option<JoinHandle<()>>,
}

enum StreamWork {
    Chunk(ChunkInFlight),
    Close,
}

/// One submitted chunk, in flight between the session thread and the
/// stream's completion worker.
struct ChunkInFlight {
    seq: u64,
    /// Pipeline-stage tasks in chain order, then the window task if one
    /// fired with this chunk.
    ids: Vec<TaskId>,
    /// Handles this chunk registered itself (freed after completion;
    /// the window accumulator's handles persist with the stream).
    owned: Vec<HandleId>,
    /// Submit time — the ack's submit-to-ack latency baseline.
    t0: Instant,
}

fn stream_open(
    shared: &Arc<Shared>,
    reply: &ReplyLane,
    mut req: StreamOpenReq,
    sid: u64,
    sess: &mut SessionState,
) {
    let fail = |e: String| {
        send_line(reply, &Response::Error { id: None, error: e });
    };
    if shared.draining.load(Ordering::SeqCst) {
        return fail("server is draining".into());
    }
    if sess.streams.contains_key(&req.id) {
        return fail(format!("stream {} is already open on this session", req.id));
    }
    // v9: one trace id for the stream's whole life — every chunk-stage
    // task rides it into the live trace ring
    if req.trace == 0 {
        req.trace = shared.next_trace.fetch_add(1, Ordering::Relaxed);
    }
    // the stream's own SLO wins; otherwise the session's hello
    // declaration drives this stream's backpressure too
    let slo = req.slo_ms.or(sess.slo_ms);
    let spec = match StreamSpec::validate(
        req.id, &req.app, req.size, req.stages, req.window, req.slide, slo,
    ) {
        Ok(s) => s,
        Err(e) => return fail(format!("{e:#}")),
    };
    let (ctx_id, ctx_name) = match shared.resolve_ctx(req.ctx.as_deref()) {
        Ok(x) => x,
        Err(e) => return fail(format!("{e:#}")),
    };
    // a stream's SLO tightens the autoscale target of its context for
    // as long as the session lives (released with the session — v5
    // declaration semantics, stream-scoped source)
    if let Some(ms) = spec.slo_ms {
        if let Some(a) = shared.autoscale.lock().unwrap().as_ref() {
            a.tighten_slo(&ctx_name, sid, ms);
        }
    }
    let rt = &shared.rt;
    let name = apps::app_codelet_name(&spec.app).to_string();
    let codelet = match rt.codelet(&name) {
        Some(c) => c,
        None => match apps::codelet(&spec.app) {
            Ok(c) => rt.register_codelet(c),
            Err(e) => return fail(format!("{e:#}")),
        },
    };
    // persistent window state, registered once per stream
    let acc = if spec.window.is_some() {
        match apps::prepare(rt, &spec.app, spec.size, spec.id ^ 0x57ea4d) {
            Ok(i) => Some(i),
            Err(e) => return fail(format!("{e:#}")),
        }
    } else {
        None
    };
    let state = Arc::new(StreamShared::new(BASE_CREDIT));
    let (tx, rx) = mpsc::channel();
    let worker = {
        let shared = shared.clone();
        let reply = reply.clone();
        let state = state.clone();
        let spec = spec.clone();
        let ctx_name = ctx_name.clone();
        std::thread::Builder::new()
            .name(format!("serve-stream-{sid}-{}", spec.id))
            .spawn(move || stream_worker(shared, reply, state, spec, ctx_id, ctx_name, rx))
            .expect("spawning stream worker")
    };
    let resp = StreamOpenedResp {
        stream: spec.id,
        credit: BASE_CREDIT,
        window: spec.window.map(|w| w.window).unwrap_or(0),
        slide: spec.window.map(|w| w.slide).unwrap_or(0),
        slo_ms: spec.slo_ms,
    };
    sess.streams.insert(
        spec.id,
        StreamHandle {
            windower: spec.window.map(Windower::new),
            spec,
            ctx_id,
            codelet,
            trace: req.trace,
            selector: sess.policy.as_ref().map(|(_, s)| s.clone()),
            state,
            acc,
            tx,
            worker: Some(worker),
        },
    );
    shared.streams.fetch_add(1, Ordering::Relaxed);
    send_line(reply, &Response::StreamOpened(resp));
}

fn stream_chunk(
    shared: &Arc<Shared>,
    reply: &ReplyLane,
    stream: u64,
    seq: u64,
    seed: u64,
    sess: &mut SessionState,
) {
    let Some(h) = sess.streams.get_mut(&stream) else {
        send_line(
            reply,
            &Response::Error {
                id: None,
                error: format!("unknown stream {stream} (open it first)"),
            },
        );
        return;
    };
    if shared.draining.load(Ordering::SeqCst) {
        send_line(
            reply,
            &Response::Error {
                id: None,
                error: "server is draining".into(),
            },
        );
        return;
    }
    // the per-stream credit loop is the primary flow control; the
    // server-wide admission gate still bounds total in-flight work
    shared.gate.acquire();
    match submit_chunk(shared, h, seq, seed) {
        Ok(chunk) => {
            if h.tx.send(StreamWork::Chunk(chunk)).is_err() {
                shared.gate.release();
            }
        }
        Err(e) => {
            h.state.dropped.fetch_add(1, Ordering::Relaxed);
            shared.requests_err.fetch_add(1, Ordering::Relaxed);
            shared.gate.release();
            send_line(
                reply,
                &Response::Error {
                    id: None,
                    error: format!("stream {stream} chunk {seq}: {e:#}"),
                },
            );
        }
    }
}

/// Register, submit and window one chunk; returns the in-flight record
/// the stream's completion worker will wait on. Every pipeline stage is
/// its own task: data dependencies chain the stages (they share the
/// chunk's handles), and each stage's variant is selected independently
/// at pop time — per-chunk, per-stage selection under live pressure.
fn submit_chunk(
    shared: &Arc<Shared>,
    h: &mut StreamHandle,
    seq: u64,
    seed: u64,
) -> Result<ChunkInFlight> {
    let rt = &shared.rt;
    let t0 = Instant::now();
    let inst = apps::prepare(rt, &h.spec.app, h.spec.size, seed)?;
    let mut ids: Vec<TaskId> = Vec::with_capacity(h.spec.stages + 1);
    for _ in 0..h.spec.stages {
        let mut spec = TaskSpec::new(h.codelet.clone(), inst.handles.clone(), h.spec.size)
            .in_context(h.ctx_id)
            .with_tag(seq)
            .with_trace(h.trace);
        if let Some(sel) = &h.selector {
            spec = spec.with_selector(sel.clone());
        }
        match rt.submit(spec) {
            Ok(id) => ids.push(id),
            Err(e) => {
                unwind_chunk(rt, &ids, &inst);
                return Err(e);
            }
        }
    }
    // window assembly at the *current* shed granularity: the completion
    // worker publishes the shed level, the submit path reads it here
    let shed = h.state.shed.load(Ordering::Relaxed);
    if let (Some(w), Some(acc)) = (h.windower.as_mut(), h.acc.as_ref()) {
        if let Some(fire) = w.push(seq, shed) {
            let mut spec = TaskSpec::new(h.codelet.clone(), acc.handles.clone(), h.spec.size)
                .in_context(h.ctx_id)
                .with_tag(seq)
                .with_trace(h.trace);
            if let Some(sel) = &h.selector {
                spec = spec.with_selector(sel.clone());
            }
            match rt.submit(spec) {
                Ok(id) => {
                    ids.push(id);
                    h.state.windows.fetch_add(1, Ordering::Relaxed);
                    // window fires are rare (one per slide), so the
                    // registry lookup is off the per-chunk hot path
                    let reg = &shared.rt.obs().registry;
                    reg.counter("stream_windows_total")
                        .fetch_add(1, Ordering::Relaxed);
                    if fire.shed {
                        h.state.shed_windows.fetch_add(1, Ordering::Relaxed);
                        reg.counter("stream_shed_windows_total")
                            .fetch_add(1, Ordering::Relaxed);
                    }
                }
                Err(e) => {
                    unwind_chunk(rt, &ids, &inst);
                    return Err(e);
                }
            }
        }
    }
    Ok(ChunkInFlight {
        seq,
        ids,
        owned: inst.owned_handles(),
        t0,
    })
}

/// Submit-failure unwind: wait out what was already submitted, then
/// free the chunk's handles (the window accumulator is untouched).
fn unwind_chunk(rt: &Runtime, ids: &[TaskId], inst: &apps::Instance) {
    let _ = rt.wait_tasks(ids);
    rt.metrics().take_results_for(ids);
    rt.reap_tasks(ids);
    for h in inst.owned_handles() {
        let _ = rt.unregister_data(h);
    }
}

/// Flush and close one stream: the worker drains every chunk already
/// queued ahead of the Close marker, emits the `stream_closed` summary,
/// then the persistent window state is freed.
fn close_stream(shared: &Arc<Shared>, mut h: StreamHandle) {
    let _ = h.tx.send(StreamWork::Close);
    if let Some(w) = h.worker.take() {
        let _ = w.join();
    }
    if let Some(acc) = h.acc.take() {
        for hd in acc.owned_handles() {
            let _ = shared.rt.unregister_data(hd);
        }
    }
    shared.streams.fetch_sub(1, Ordering::Relaxed);
}

/// Per-stream completion worker: drains the stream's chunks in order
/// (one thread per stream keeps acks in sequence order), prices the
/// backlog in wall milliseconds, drives the credit controller, and
/// pushes an unsolicited `stream_credit` whenever the shed level moves.
fn stream_worker(
    shared: Arc<Shared>,
    reply: ReplyLane,
    state: Arc<StreamShared>,
    spec: StreamSpec,
    ctx_id: CtxId,
    ctx_name: String,
    rx: mpsc::Receiver<StreamWork>,
) {
    let rt = &shared.rt;
    let mut credit = CreditController::new(spec.slo_ms, BASE_CREDIT);
    let mut backlog = BacklogModel::default();
    let mut latency = LatencyTrack::default();
    // v9: per-chunk instruments, cached once — the loop records through
    // plain atomics, never the registry's name map
    let chunks_total = rt.obs().registry.counter("stream_chunks_total");
    let credit_signals_total = rt.obs().registry.counter("stream_credit_signals_total");
    while let Ok(StreamWork::Chunk(c)) = rx.recv() {
        let waited = rt.wait_tasks(&c.ids);
        let results = rt.metrics().take_results_for(&c.ids);
        if let Some(n) = shared.ctx_tasks.get(ctx_id) {
            n.fetch_add(results.len() as u64, Ordering::Relaxed);
        }
        {
            let mut hists = shared.ctx_variants.lock().unwrap();
            if let Some(hist) = hists.get_mut(ctx_id) {
                for r in &results {
                    *hist.entry(r.variant.clone()).or_insert(0) += 1;
                }
            }
        }
        // the backlog model prices the queue in the SLO's domain:
        // measured wall seconds per task, not modeled device micros
        for r in &results {
            backlog.observe(r.wall);
        }
        rt.reap_tasks(&c.ids);
        for hd in &c.owned {
            let _ = rt.unregister_data(*hd);
        }
        let lat = c.t0.elapsed().as_secs_f64();
        let queued_ms = backlog.queued_ms(rt.queued_tasks());
        let d = credit.assess(queued_ms);
        state.shed.store(d.shed, Ordering::Relaxed);
        state.credit.store(d.credit, Ordering::Relaxed);
        // ack and (when the controller moved) credit signal go out as
        // one coalesced write, not two syscalls per chunk
        let mut out: Vec<Response> = Vec::with_capacity(2);
        match waited {
            Ok(()) => {
                latency.record(lat);
                state.chunks.fetch_add(1, Ordering::Relaxed);
                shared.requests_ok.fetch_add(1, Ordering::Relaxed);
                chunks_total.fetch_add(1, Ordering::Relaxed);
                // chunk end-to-end: submit -> ack (success only, so the
                // histogram count reconciles with requests_ok)
                rt.obs().e2e_seconds().observe(lat);
                out.push(Response::StreamAck(StreamAckResp {
                    stream: spec.id,
                    seq: c.seq,
                    ctx: ctx_name.clone(),
                    variants: results.iter().map(|r| r.variant.clone()).collect(),
                    workers: results.iter().map(|r| r.worker).collect(),
                    modeled: results.iter().map(|r| r.modeled_total()).sum(),
                    wall: results.iter().map(|r| r.wall).sum(),
                    latency: lat,
                    credit: d.credit,
                    shed: u64::from(d.shed),
                }));
            }
            Err(e) => {
                state.dropped.fetch_add(1, Ordering::Relaxed);
                shared.requests_err.fetch_add(1, Ordering::Relaxed);
                out.push(Response::Error {
                    id: None,
                    error: format!("stream {} chunk {}: {e:#}", spec.id, c.seq),
                });
            }
        }
        if d.changed {
            state.credit_signals.fetch_add(1, Ordering::Relaxed);
            credit_signals_total.fetch_add(1, Ordering::Relaxed);
            out.push(Response::StreamCredit(StreamCreditResp {
                stream: spec.id,
                credit: d.credit,
                shed: u64::from(d.shed),
                queued_ms,
            }));
        }
        send_batch(&reply, &out);
        shared.gate.release();
    }
    // Close marker (or the session dropped the sender): flush summary
    send_line(
        &reply,
        &Response::StreamClosed(StreamClosedResp {
            stream: spec.id,
            chunks: state.chunks.load(Ordering::Relaxed),
            dropped: state.dropped.load(Ordering::Relaxed),
            windows: state.windows.load(Ordering::Relaxed),
            shed_windows: state.shed_windows.load(Ordering::Relaxed),
            credit_signals: state.credit_signals.load(Ordering::Relaxed),
            p95_ms: latency.p95_ms(),
        }),
    );
}

// -------------------------------------------------------- dispatch + exec

fn dispatch_loop(shared: Arc<Shared>) {
    let window = {
        let shared = shared.clone();
        move || adaptive_window(shared.batcher.window, &shared.rt)
    };
    while let Some(batches) = shared.batcher.collect(&window) {
        for (_app, mut jobs) in batches {
            while !jobs.is_empty() {
                let take = jobs.len().min(shared.batcher.max_batch);
                let chunk: Vec<Job> = jobs.drain(..take).collect();
                run_batch(&shared, chunk);
            }
        }
        // prune finished completion threads so the list stays bounded
        crate::util::threads::reap_finished(&mut shared.completions.lock().unwrap());
    }
}

/// Submit one batch of same-app jobs and hand completion to a worker
/// thread (submission itself is cheap; waiting must not block the
/// dispatcher, or contexts could not make progress concurrently).
///
/// Zero-copy batching: riders with identical (size, seed) — the app is
/// already identical within a batch — share one registration of their
/// read-only input handles ([`apps::shared_input_indices`]). The batch
/// group owns those handles and frees them only after every rider has
/// completed, so concurrent readers never race an unregister.
fn run_batch(shared: &Arc<Shared>, jobs: Vec<Job>) {
    let batch_size = jobs.len();
    // v9: the fuse itself is observable — a monotonic fused-batch
    // counter plus a batch-window span on the dispatcher lane covering
    // admission -> submit for the batch's oldest rider
    if batch_size > 1 {
        shared.batches_fused.fetch_add(1, Ordering::Relaxed);
    }
    if let Some(first) = jobs.first() {
        let obs = shared.rt.obs();
        let t_end = obs.now_secs();
        obs.trace.push(SpanEvent {
            name: format!("batch:{}x{batch_size}", first.req.app),
            cat: "serve",
            lane: 0,
            lane_name: "dispatcher".into(),
            trace: first.trace,
            t_start: (t_end - first.admitted.elapsed().as_secs_f64()).max(0.0),
            t_end,
        });
    }
    let mut submitted = Vec::new();
    // (size, seed) -> the shared input handles registered by the first
    // identical rider
    let mut donors: HashMap<(usize, u64), Vec<(usize, crate::taskrt::HandleId)>> = HashMap::new();
    let mut group_handles: Vec<crate::taskrt::HandleId> = Vec::new();
    for job in jobs {
        match submit_job(shared, &job, &mut donors, &mut group_handles) {
            Ok((inst, ids)) => submitted.push((job, inst, ids)),
            Err(e) => {
                shared.requests_err.fetch_add(1, Ordering::Relaxed);
                send_line(
                    &job.reply,
                    &Response::Error {
                        id: Some(job.req.id),
                        error: format!("{e:#}"),
                    },
                );
                shared.gate.release();
            }
        }
    }
    if submitted.is_empty() {
        for h in group_handles {
            let _ = shared.rt.unregister_data(h);
        }
        return;
    }
    let shared2 = shared.clone();
    let handle = std::thread::Builder::new()
        .name("serve-complete".into())
        .spawn(move || {
            // group the batch's replies per lane: one coalesced write
            // per session instead of one syscall per result
            let mut by_lane: Vec<(ReplyLane, Vec<Response>)> = Vec::new();
            for (job, inst, ids) in submitted {
                let (lane, resp) = complete_job(&shared2, job, inst, ids, batch_size);
                match by_lane.iter_mut().find(|(l, _)| Arc::ptr_eq(l, &lane)) {
                    Some((_, v)) => v.push(resp),
                    None => by_lane.push((lane, vec![resp])),
                }
            }
            for (lane, resps) in by_lane {
                send_batch(&lane, &resps);
            }
            // every rider is done: release the shared input handles
            for h in group_handles {
                let _ = shared2.rt.unregister_data(h);
            }
        })
        .expect("spawning completion thread");
    shared.completions.lock().unwrap().push(handle);
}

/// Validate, register (sharing read-only inputs with identical riders in
/// the same batch) and submit one request's task chain.
fn submit_job(
    shared: &Arc<Shared>,
    job: &Job,
    donors: &mut HashMap<(usize, u64), Vec<(usize, crate::taskrt::HandleId)>>,
    group_handles: &mut Vec<crate::taskrt::HandleId>,
) -> Result<(apps::Instance, Vec<TaskId>)> {
    let rt = &shared.rt;
    if job.req.tasks > 1 && !apps::idempotent(&job.req.app) {
        bail!(
            "app '{}' mutates its input in place; a verified task chain \
             (tasks > 1) is only supported for idempotent apps {:?}",
            job.req.app,
            apps::IDEMPOTENT
        );
    }
    let name = apps::app_codelet_name(&job.req.app).to_string();
    let cl = match rt.codelet(&name) {
        Some(c) => c,
        None => rt.register_codelet(apps::codelet(&job.req.app)?),
    };
    // validate a pinned variant against the codelet's registered
    // variants up front: a typo is a protocol error, never a silent
    // fallback to runtime selection
    if let Some(v) = &job.req.variant {
        if cl.impl_by_name(v).is_none() {
            let known: Vec<&str> = cl.impls.iter().map(|i| i.name.as_str()).collect();
            bail!(
                "unknown variant '{v}' for app '{}' (registered: {})",
                job.req.app,
                known.join(", ")
            );
        }
    }
    // register the instance, sharing read-only inputs with identical
    // riders (zero-copy batching)
    let share = apps::shared_input_indices(&job.req.app);
    let inst = if share.is_empty() {
        apps::prepare(rt, &job.req.app, job.req.size, job.req.seed)?
    } else {
        let key = (job.req.size, job.req.seed);
        match donors.get(&key) {
            Some(inputs) => {
                apps::prepare_with_inputs(rt, &job.req.app, job.req.size, job.req.seed, inputs)?
            }
            None => {
                let mut inst = apps::prepare(rt, &job.req.app, job.req.size, job.req.seed)?;
                let donated = inst.donate_handles(share);
                group_handles.extend(donated.iter().map(|(_, h)| *h));
                donors.insert(key, donated);
                inst
            }
        }
    };
    let mut ids: Vec<TaskId> = Vec::with_capacity(job.req.tasks);
    for _ in 0..job.req.tasks {
        let mut spec = TaskSpec::new(cl.clone(), inst.handles.clone(), job.req.size)
            .in_context(job.ctx_id)
            .with_trace(job.trace);
        if let Some(v) = &job.req.variant {
            spec = spec.with_variant(v);
        } else if let Some(sel) = &job.selector {
            spec = spec.with_selector(sel.clone());
        }
        match rt.submit(spec) {
            Ok(id) => ids.push(id),
            Err(e) => {
                // unwind: wait out what we already submitted, then free
                // (shared inputs stay registered — the group frees them)
                let _ = rt.wait_tasks(&ids);
                rt.metrics().take_results_for(&ids);
                rt.reap_tasks(&ids);
                for h in inst.owned_handles() {
                    let _ = rt.unregister_data(h);
                }
                return Err(e);
            }
        }
    }
    Ok((inst, ids))
}

/// Wait for one request's tasks, verify, clean up, release; the reply
/// itself is returned so the completion thread can coalesce a whole
/// batch's responses into one write per reply lane.
fn complete_job(
    shared: &Arc<Shared>,
    job: Job,
    inst: apps::Instance,
    ids: Vec<TaskId>,
    batch: usize,
) -> (ReplyLane, Response) {
    let rt = &shared.rt;
    let waited = rt.wait_tasks(&ids);
    let results = rt.metrics().take_results_for(&ids);
    if let Some(c) = shared.ctx_tasks.get(job.ctx_id) {
        c.fetch_add(results.len() as u64, Ordering::Relaxed);
    }
    {
        let mut hists = shared.ctx_variants.lock().unwrap();
        if let Some(h) = hists.get_mut(job.ctx_id) {
            for r in &results {
                *h.entry(r.variant.clone()).or_insert(0) += 1;
            }
        }
    }

    let outcome = waited.and_then(|()| {
        let mut rel_err = 0.0f64;
        if job.req.verify {
            let got = rt.snapshot(apps::output_handle(&inst))?;
            let want = apps::expected(&inst)?;
            let err = got.rel_l2_error(&want);
            if err > apps::tolerance(&job.req.app) {
                bail!(
                    "verification failed: rel L2 error {err} exceeds {}",
                    apps::tolerance(&job.req.app)
                );
            }
            rel_err = err as f64;
        }
        Ok(ResultResp {
            id: job.req.id,
            app: job.req.app.clone(),
            size: job.req.size,
            ctx: job.ctx_name.clone(),
            policy: job.policy_name.clone(),
            variants: results.iter().map(|r| r.variant.clone()).collect(),
            workers: results.iter().map(|r| r.worker).collect(),
            batch,
            modeled: results.iter().map(|r| r.modeled_total()).sum(),
            wall: results.iter().map(|r| r.wall).sum(),
            rel_err,
            trace: job.trace,
        })
    });

    rt.reap_tasks(&ids);
    // free only the handles this request registered itself; shared
    // zero-copy inputs belong to the batch group
    for h in inst.owned_handles() {
        let _ = rt.unregister_data(h);
    }

    let resp = match outcome {
        Ok(resp) => {
            shared.requests_ok.fetch_add(1, Ordering::Relaxed);
            // v9: end-to-end latency, admission -> reply; observed only
            // for successes so the histogram's count reconciles with
            // `requests_ok` and loadgen's success count
            shared
                .rt
                .obs()
                .e2e_seconds()
                .observe(job.admitted.elapsed().as_secs_f64());
            Response::Result(resp)
        }
        Err(e) => {
            shared.requests_err.fetch_add(1, Ordering::Relaxed);
            Response::Error {
                id: Some(job.req.id),
                error: format!("{e:#}"),
            }
        }
    };
    shared.gate.release();
    (job.reply, resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_spec_parsing() {
        let v = parse_contexts("cpu:4,gpu:1").unwrap();
        assert_eq!(v.len(), 2);
        assert_eq!(
            v[0],
            CtxSpec {
                name: "cpu".into(),
                count: 4,
                arch: Arch::Cpu,
                selector: None
            }
        );
        assert_eq!(
            v[1],
            CtxSpec {
                name: "gpu".into(),
                count: 1,
                arch: Arch::Cuda,
                selector: None
            }
        );
        let v = parse_contexts("alpha:2, cuda0:3").unwrap();
        assert_eq!(v[0].arch, Arch::Cpu);
        assert_eq!(v[1].arch, Arch::Cuda);
        assert!(parse_contexts("bad").is_err());
        assert!(parse_contexts("x:0").is_err());
        assert!(parse_contexts(":3").is_err());
        assert!(parse_contexts("").unwrap().is_empty());
    }

    #[test]
    fn context_spec_parses_per_context_selector() {
        let v = parse_contexts("a:2:greedy,b:2:epsilon:0.2,c:1:forced:omp").unwrap();
        assert_eq!(v[0].selector, Some(SelectorKind::Greedy));
        assert_eq!(v[1].selector, Some(SelectorKind::EpsilonGreedy(0.2)));
        assert_eq!(v[2].selector, Some(SelectorKind::Forced("omp".into())));
        assert!(parse_contexts("a:2:bogus").is_err());
    }

    #[test]
    fn adaptive_window_shrinks_when_idle() {
        // a fully idle runtime pays pure latency for batching: the
        // snapshot-aware window must shrink below the configured base
        let rt = Runtime::new(
            Config {
                ncpu: 1,
                ncuda: 0,
                ..Config::default()
            },
            None,
        )
        .unwrap();
        let base = Duration::from_micros(400);
        assert_eq!(adaptive_window(base, &rt), base / 4);
    }

    #[test]
    fn adaptive_window_widens_under_sustained_pressure_and_recovers() {
        use crate::runtime::Tensor;
        use crate::taskrt::{AccessMode, NativeFn};
        // one slow worker, a deep queue: sustained pressure must hold
        // the fuse window at its 4x cap (not just a transient burst),
        // and draining must bring it back to the idle quarter
        let rt = Runtime::new(
            Config {
                ncpu: 1,
                ncuda: 0,
                sched: SchedPolicy::Eager,
                ..Config::default()
            },
            None,
        )
        .unwrap();
        let nap: NativeFn = Arc::new(|_bufs| {
            std::thread::sleep(Duration::from_millis(20));
            Ok(())
        });
        let cl = rt.register_codelet(
            Codelet::new("nap", "nap", vec![AccessMode::Read]).with_native(
                "seq",
                Arch::Cpu,
                nap,
            ),
        );
        // distinct handles: no data dependencies, every task queues
        // ready behind the single worker
        let handles: Vec<_> = (0..8)
            .map(|_| rt.register_data(Tensor::zeros(vec![4])))
            .collect();
        for &h in &handles {
            rt.submit(TaskSpec::new(cl.clone(), vec![h], 4)).unwrap();
        }
        let base = Duration::from_micros(400);
        assert_eq!(
            adaptive_window(base, &rt),
            base.mul_f64(4.0),
            "a deep sustained queue pins the window at its 4x cap"
        );
        rt.wait_all().unwrap();
        assert_eq!(
            adaptive_window(base, &rt),
            base / 4,
            "a drained runtime returns to the idle quarter"
        );
        for h in handles {
            let _ = rt.unregister_data(h);
        }
    }

    #[test]
    fn gate_blocks_at_cap() {
        let gate = Arc::new(Gate::new(2));
        gate.acquire();
        gate.acquire();
        assert_eq!(gate.inflight(), 2);
        let g2 = gate.clone();
        let t = std::thread::spawn(move || {
            g2.acquire(); // blocks until a release
            g2.inflight()
        });
        std::thread::sleep(Duration::from_millis(50));
        assert!(!t.is_finished(), "third acquire must block at cap 2");
        gate.release();
        assert_eq!(t.join().unwrap(), 2);
    }
}
