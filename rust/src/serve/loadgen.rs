//! Load generator for the component service: N client threads, each
//! with its own connection, each firing M requests — synchronously by
//! default, or with up to `--pipeline` requests in flight per
//! connection (the wire protocol's correlation ids match out-of-order
//! completions). Reports throughput and the latency distribution
//! (p50/p95/p99) plus variant and context histograms — the serving-path
//! scaling instrument.

use std::collections::{BTreeMap, HashMap};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::client::Client;
use super::protocol::{Response, SubmitReq};
use crate::util::json::Json;
use crate::util::stats;

/// Time-varying offered load (`--profile burst:<high>:<low>:<period_ms>`):
/// without one, every client fires as fast as the closed loop allows;
/// with one, each client paces its sends to the phase's offered rate.
/// The bursty shape is what the autoscale bench (and any elastic-scaling
/// demo) needs: pressure that arrives in waves rather than a constant
/// stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadProfile {
    /// Alternate between `high` and `low` offered requests/s per
    /// client, switching phase every `period_ms`.
    Burst { high: f64, low: f64, period_ms: u64 },
}

impl LoadProfile {
    /// Parse `burst:<high_rps>:<low_rps>:<period_ms>`.
    pub fn parse(s: &str) -> Result<LoadProfile> {
        let parts: Vec<&str> = s.split(':').map(str::trim).collect();
        match parts.as_slice() {
            ["burst", h, l, p] => {
                let high: f64 = h.parse().context("burst high rate")?;
                let low: f64 = l.parse().context("burst low rate")?;
                let period_ms: u64 = p.parse().context("burst period")?;
                if high.is_nan() || high <= 0.0 || low.is_nan() || low < 0.0 || period_ms == 0 {
                    bail!("bad burst profile '{s}' (need high > 0, low >= 0, period > 0)");
                }
                Ok(LoadProfile::Burst {
                    high,
                    low,
                    period_ms,
                })
            }
            _ => bail!("unknown load profile '{s}' (want burst:<high>:<low>:<period_ms>)"),
        }
    }

    pub fn name(&self) -> String {
        match self {
            LoadProfile::Burst {
                high,
                low,
                period_ms,
            } => format!("burst:{high}:{low}:{period_ms}"),
        }
    }

    /// Offered per-client rate (req/s) at `elapsed` since the run
    /// started.
    pub fn rate_at(&self, elapsed: Duration) -> f64 {
        match self {
            LoadProfile::Burst {
                high,
                low,
                period_ms,
            } => {
                if (elapsed.as_millis() as u64 / period_ms) % 2 == 0 {
                    *high
                } else {
                    *low
                }
            }
        }
    }
}

/// Paces one client's sends to a [`LoadProfile`] (no-op without one).
struct Pacer {
    profile: Option<LoadProfile>,
    t0: Instant,
    last: Option<Instant>,
}

impl Pacer {
    fn new(profile: Option<LoadProfile>) -> Pacer {
        Pacer {
            profile,
            t0: Instant::now(),
            last: None,
        }
    }

    /// Block until the profile grants the next send slot.
    fn wait(&mut self) {
        let Some(p) = self.profile else { return };
        loop {
            let now = Instant::now();
            let rate = p.rate_at(now.duration_since(self.t0));
            if rate > 0.0 {
                let due = match self.last {
                    Some(last) => last + Duration::from_secs_f64(1.0 / rate),
                    None => now,
                };
                if now >= due {
                    self.last = Some(now);
                    return;
                }
                std::thread::sleep((due - now).min(Duration::from_millis(5)));
            } else {
                // zero-rate phase: idle until the profile wakes up
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests per client.
    pub requests: usize,
    pub app: String,
    pub size: usize,
    /// Tasks per request (dependency chain length).
    pub tasks: usize,
    /// Contexts to spread requests over, round-robin per client
    /// (empty = server default routing).
    pub ctxs: Vec<String>,
    /// Requests kept in flight per connection (1 = synchronous).
    pub pipeline: usize,
    /// Per-session selection policy (hello handshake); None = the
    /// context's policy.
    pub policy: Option<String>,
    /// Time-varying offered load; None = closed-loop, as fast as
    /// possible.
    pub profile: Option<LoadProfile>,
    pub verify: bool,
    pub seed: u64,
}

impl Default for LoadgenOptions {
    fn default() -> LoadgenOptions {
        LoadgenOptions {
            clients: 8,
            requests: 100,
            app: "matmul".into(),
            size: 48,
            tasks: 1,
            ctxs: Vec::new(),
            pipeline: 1,
            policy: None,
            profile: None,
            verify: true,
            seed: 42,
        }
    }
}

/// Aggregate outcome of one load-generation run (seconds throughout).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub clients: usize,
    pub requests: usize,
    /// Requests in flight per connection during the run.
    pub pipeline: usize,
    pub errors: usize,
    pub elapsed: f64,
    /// Successful requests per second of wall time.
    pub rps: f64,
    pub lat_mean: f64,
    pub lat_min: f64,
    pub lat_max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// variant name -> tasks executed with it.
    pub variants: BTreeMap<String, usize>,
    /// context name -> requests served under it.
    pub per_ctx: BTreeMap<String, usize>,
    /// Requests that shared a codelet batch with at least one other.
    pub batched: usize,
    pub max_rel_err: f64,
}

struct ClientOutcome {
    latencies: Vec<f64>,
    errors: usize,
    variants: BTreeMap<String, usize>,
    per_ctx: BTreeMap<String, usize>,
    batched: usize,
    max_rel_err: f64,
}

fn request_for(opts: &LoadgenOptions, client_idx: usize, r: usize) -> SubmitReq {
    let ctx = if opts.ctxs.is_empty() {
        None
    } else {
        Some(opts.ctxs[(client_idx + r) % opts.ctxs.len()].clone())
    };
    SubmitReq {
        id: r as u64,
        app: opts.app.clone(),
        size: opts.size,
        tasks: opts.tasks,
        ctx,
        seed: opts
            .seed
            .wrapping_add((client_idx as u64) << 20)
            .wrapping_add(r as u64),
        variant: None,
        verify: opts.verify,
    }
}

fn tally(out: &mut ClientOutcome, resp: &super::protocol::ResultResp, latency: f64) {
    out.latencies.push(latency);
    for v in &resp.variants {
        *out.variants.entry(v.clone()).or_insert(0) += 1;
    }
    *out.per_ctx.entry(resp.ctx.clone()).or_insert(0) += 1;
    if resp.batch > 1 {
        out.batched += 1;
    }
    out.max_rel_err = out.max_rel_err.max(resp.rel_err);
}

fn drive_client(addr: &str, opts: &LoadgenOptions, client_idx: usize) -> Result<ClientOutcome> {
    let mut c = Client::connect_with_policy(addr, opts.policy.as_deref())?;
    let mut out = ClientOutcome {
        latencies: Vec::with_capacity(opts.requests),
        errors: 0,
        variants: BTreeMap::new(),
        per_ctx: BTreeMap::new(),
        batched: 0,
        max_rel_err: 0.0,
    };
    let window = opts.pipeline.max(1);
    let mut pacer = Pacer::new(opts.profile);
    if window == 1 {
        // synchronous: one outstanding request, honest per-request latency
        for r in 0..opts.requests {
            pacer.wait();
            let req = request_for(opts, client_idx, r);
            let t0 = Instant::now();
            match c.submit(req) {
                Ok(resp) => tally(&mut out, &resp, t0.elapsed().as_secs_f64()),
                Err(_) => out.errors += 1,
            }
        }
    } else {
        // pipelined: keep up to `window` requests in flight; replies may
        // come back out of order, so match them by correlation id. A
        // transport or protocol failure kills this connection only:
        // everything unsent or unanswered counts as an error, matching
        // the synchronous path's keep-going semantics.
        let mut pending: HashMap<u64, Instant> = HashMap::new();
        let mut next = 0usize;
        let mut dead = false;
        while !dead && (next < opts.requests || !pending.is_empty()) {
            while pending.len() < window && next < opts.requests {
                pacer.wait();
                let req = request_for(opts, client_idx, next);
                let id = req.id;
                if c.send_submit(req).is_err() {
                    dead = true;
                    break;
                }
                pending.insert(id, Instant::now());
                next += 1;
            }
            if dead {
                break;
            }
            match c.recv_response() {
                Ok(Response::Result(resp)) => match pending.remove(&resp.id) {
                    Some(t0) => tally(&mut out, &resp, t0.elapsed().as_secs_f64()),
                    None => dead = true, // unsolicited id: protocol confusion
                },
                Ok(Response::Error { id, .. }) => match id {
                    Some(id) => {
                        pending.remove(&id);
                        out.errors += 1;
                    }
                    // an id-less error can't be matched to a pending
                    // request; waiting on would hang forever — give up
                    // on the connection (tail accounting records the
                    // outstanding requests as errors)
                    None => dead = true,
                },
                Ok(_) | Err(_) => dead = true,
            }
        }
        out.errors += pending.len() + opts.requests.saturating_sub(next);
    }
    let _ = c.quit();
    Ok(out)
}

/// Run the load against a listening server.
pub fn run(addr: &str, opts: &LoadgenOptions) -> Result<LoadReport> {
    if opts.clients == 0 || opts.requests == 0 {
        return Err(anyhow!("need at least one client and one request"));
    }
    let t0 = Instant::now();
    let outcomes: Vec<Result<ClientOutcome>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..opts.clients)
            .map(|i| {
                let addr = addr.to_string();
                let opts = opts.clone();
                s.spawn(move || drive_client(&addr, &opts, i))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("client thread panicked")))
            })
            .collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let mut latencies = Vec::new();
    let mut errors = 0usize;
    let mut variants = BTreeMap::new();
    let mut per_ctx = BTreeMap::new();
    let mut batched = 0usize;
    let mut max_rel_err = 0.0f64;
    for o in outcomes {
        let o = o?;
        latencies.extend(o.latencies);
        errors += o.errors;
        for (k, v) in o.variants {
            *variants.entry(k).or_insert(0) += v;
        }
        for (k, v) in o.per_ctx {
            *per_ctx.entry(k).or_insert(0) += v;
        }
        batched += o.batched;
        max_rel_err = max_rel_err.max(o.max_rel_err);
    }
    if latencies.is_empty() {
        return Err(anyhow!("no request succeeded ({errors} errors)"));
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = latencies.len();
    Ok(LoadReport {
        clients: opts.clients,
        requests: n + errors,
        pipeline: opts.pipeline.max(1),
        errors,
        elapsed,
        rps: n as f64 / elapsed,
        lat_mean: latencies.iter().sum::<f64>() / n as f64,
        lat_min: latencies[0],
        lat_max: latencies[n - 1],
        p50: stats::percentile(&latencies, 50.0),
        p95: stats::percentile(&latencies, 95.0),
        p99: stats::percentile(&latencies, 99.0),
        variants,
        per_ctx,
        batched,
        max_rel_err,
    })
}

/// Plain-text report.
pub fn render(r: &LoadReport) -> String {
    let mut out = String::new();
    out.push_str("== compar loadgen report ==\n");
    out.push_str(&format!(
        "clients {}  requests {}  pipeline {}  errors {}  elapsed {:.3} s\n",
        r.clients, r.requests, r.pipeline, r.errors, r.elapsed
    ));
    out.push_str(&format!("throughput {:.1} req/s\n", r.rps));
    out.push_str(&format!(
        "latency mean {}  min {}  max {}\n",
        stats::fmt_time(r.lat_mean),
        stats::fmt_time(r.lat_min),
        stats::fmt_time(r.lat_max)
    ));
    out.push_str(&format!(
        "latency p50 {}  p95 {}  p99 {}\n",
        stats::fmt_time(r.p50),
        stats::fmt_time(r.p95),
        stats::fmt_time(r.p99)
    ));
    if !r.per_ctx.is_empty() {
        let cells: Vec<String> = r
            .per_ctx
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        out.push_str(&format!("requests per context: {}\n", cells.join("  ")));
    }
    if !r.variants.is_empty() {
        let cells: Vec<String> = r
            .variants
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        out.push_str(&format!("variant selection: {}\n", cells.join("  ")));
    }
    out.push_str(&format!(
        "batched requests {}  max rel L2 err {:.2e}\n",
        r.batched, r.max_rel_err
    ));
    out
}

/// JSON form (BENCH_serve.json baseline record).
pub fn to_json(r: &LoadReport) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("clients".into(), Json::Num(r.clients as f64));
    m.insert("requests".into(), Json::Num(r.requests as f64));
    m.insert("pipeline".into(), Json::Num(r.pipeline as f64));
    m.insert("errors".into(), Json::Num(r.errors as f64));
    m.insert("elapsed_s".into(), Json::Num(r.elapsed));
    m.insert("rps".into(), Json::Num(r.rps));
    m.insert("lat_mean_s".into(), Json::Num(r.lat_mean));
    m.insert("lat_min_s".into(), Json::Num(r.lat_min));
    m.insert("lat_max_s".into(), Json::Num(r.lat_max));
    m.insert("p50_s".into(), Json::Num(r.p50));
    m.insert("p95_s".into(), Json::Num(r.p95));
    m.insert("p99_s".into(), Json::Num(r.p99));
    m.insert("batched".into(), Json::Num(r.batched as f64));
    m.insert("max_rel_err".into(), Json::Num(r.max_rel_err));
    let mut variants = std::collections::BTreeMap::new();
    for (k, v) in &r.variants {
        variants.insert(k.clone(), Json::Num(*v as f64));
    }
    m.insert("variants".into(), Json::Obj(variants));
    let mut per_ctx = std::collections::BTreeMap::new();
    for (k, v) in &r.per_ctx {
        per_ctx.insert(k.clone(), Json::Num(*v as f64));
    }
    m.insert("per_ctx".into(), Json::Obj(per_ctx));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_profile_parses_and_phases() {
        let p = LoadProfile::parse("burst:40:2:300").unwrap();
        assert_eq!(
            p,
            LoadProfile::Burst {
                high: 40.0,
                low: 2.0,
                period_ms: 300
            }
        );
        assert_eq!(p.name(), "burst:40:2:300");
        // phase 0 is high, phase 1 low, phase 2 high again
        assert_eq!(p.rate_at(Duration::from_millis(0)), 40.0);
        assert_eq!(p.rate_at(Duration::from_millis(299)), 40.0);
        assert_eq!(p.rate_at(Duration::from_millis(300)), 2.0);
        assert_eq!(p.rate_at(Duration::from_millis(650)), 40.0);
    }

    #[test]
    fn burst_profile_rejects_malformed() {
        assert!(LoadProfile::parse("burst:40:2").is_err());
        assert!(LoadProfile::parse("burst:0:2:300").is_err());
        assert!(LoadProfile::parse("burst:40:-1:300").is_err());
        assert!(LoadProfile::parse("burst:40:2:0").is_err());
        assert!(LoadProfile::parse("ramp:1:2:3").is_err());
        assert!(LoadProfile::parse("burst:x:2:300").is_err());
    }
}
