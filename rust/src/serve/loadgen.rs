//! Load generator for the component service: N client threads, each
//! with its own connection, each firing M requests — synchronously by
//! default, or with up to `--pipeline` requests in flight per
//! connection (the wire protocol's correlation ids match out-of-order
//! completions). Reports throughput and the latency distribution
//! (p50/p95/p99) plus variant and context histograms — the serving-path
//! scaling instrument.

use std::collections::{BTreeMap, HashMap};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::client::{Client, ClientConfig};
use super::protocol::{Response, StreamOpenReq, SubmitReq};
use super::transport::Framing;
use crate::util::json::Json;
use crate::util::stats;

/// Time-varying offered load (`--profile burst:<high>:<low>:<period_ms>`
/// or `--profile stream:<rate>:<chunk_kb>:<stages>`): without one, every
/// client fires as fast as the closed loop allows; with one, each client
/// paces its sends to the phase's offered rate. The bursty shape is what
/// the autoscale bench (and any elastic-scaling demo) needs: pressure
/// that arrives in waves rather than a constant stream. The stream shape
/// switches the driver to v6 stream sessions: each client opens one
/// stream and pushes chunks at the offered rate under the server's
/// credit window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadProfile {
    /// Alternate between `high` and `low` offered requests/s per
    /// client, switching phase every `period_ms`.
    Burst { high: f64, low: f64, period_ms: u64 },
    /// v6: one stream session per client, `rate` offered chunks/s,
    /// `chunk_kb` kilobytes of payload per chunk, a `stages`-deep
    /// codelet pipeline per chunk.
    Stream {
        rate: f64,
        chunk_kb: usize,
        stages: usize,
    },
}

impl LoadProfile {
    /// Parse `burst:<high_rps>:<low_rps>:<period_ms>` or
    /// `stream:<rate>:<chunk_kb>:<stages>`.
    pub fn parse(s: &str) -> Result<LoadProfile> {
        let parts: Vec<&str> = s.split(':').map(str::trim).collect();
        match parts.as_slice() {
            ["burst", h, l, p] => {
                let high: f64 = h.parse().context("burst high rate")?;
                let low: f64 = l.parse().context("burst low rate")?;
                let period_ms: i64 = p.parse().context("burst period")?;
                if high.is_nan() || high <= 0.0 || low.is_nan() || low < 0.0 || period_ms <= 0 {
                    bail!("bad burst profile '{s}' (need high > 0, low >= 0, period > 0)");
                }
                Ok(LoadProfile::Burst {
                    high,
                    low,
                    period_ms: period_ms as u64,
                })
            }
            ["stream", r, kb, st] => {
                let rate: f64 = r.parse().context("stream chunk rate")?;
                let chunk_kb: i64 = kb.parse().context("stream chunk size (KiB)")?;
                let stages: i64 = st.parse().context("stream pipeline stages")?;
                if rate.is_nan() || rate <= 0.0 || chunk_kb <= 0 || stages <= 0 {
                    bail!("bad stream profile '{s}' (need rate > 0, chunk_kb > 0, stages > 0)");
                }
                Ok(LoadProfile::Stream {
                    rate,
                    chunk_kb: chunk_kb as usize,
                    stages: stages as usize,
                })
            }
            _ => bail!(
                "unknown load profile '{s}' (want burst:<high>:<low>:<period_ms> \
                 or stream:<rate>:<chunk_kb>:<stages>)"
            ),
        }
    }

    pub fn name(&self) -> String {
        match self {
            LoadProfile::Burst {
                high,
                low,
                period_ms,
            } => format!("burst:{high}:{low}:{period_ms}"),
            LoadProfile::Stream {
                rate,
                chunk_kb,
                stages,
            } => format!("stream:{rate}:{chunk_kb}:{stages}"),
        }
    }

    /// Offered per-client rate (req/s) at `elapsed` since the run
    /// started.
    pub fn rate_at(&self, elapsed: Duration) -> f64 {
        match self {
            LoadProfile::Burst {
                high,
                low,
                period_ms,
            } => {
                // parse() rejects a zero period, but the struct can be
                // built directly — pin the degenerate case to the high
                // phase instead of dividing by zero
                if *period_ms == 0 {
                    return *high;
                }
                if (elapsed.as_millis() as u64 / period_ms) % 2 == 0 {
                    *high
                } else {
                    *low
                }
            }
            LoadProfile::Stream { rate, .. } => *rate,
        }
    }
}

/// Paces one client's sends to a [`LoadProfile`] (no-op without one).
struct Pacer {
    profile: Option<LoadProfile>,
    t0: Instant,
    last: Option<Instant>,
}

impl Pacer {
    fn new(profile: Option<LoadProfile>) -> Pacer {
        Pacer {
            profile,
            t0: Instant::now(),
            last: None,
        }
    }

    /// Block until the profile grants the next send slot.
    fn wait(&mut self) {
        let Some(p) = self.profile else { return };
        loop {
            let now = Instant::now();
            let rate = p.rate_at(now.duration_since(self.t0));
            if rate > 0.0 {
                let due = match self.last {
                    Some(last) => last + Duration::from_secs_f64(1.0 / rate),
                    None => now,
                };
                if now >= due {
                    self.last = Some(now);
                    return;
                }
                std::thread::sleep((due - now).min(Duration::from_millis(5)));
            } else {
                // zero-rate phase: idle until the profile wakes up
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests per client.
    pub requests: usize,
    pub app: String,
    pub size: usize,
    /// Tasks per request (dependency chain length).
    pub tasks: usize,
    /// Contexts to spread requests over, round-robin per client
    /// (empty = server default routing).
    pub ctxs: Vec<String>,
    /// Requests kept in flight per connection (1 = synchronous).
    pub pipeline: usize,
    /// Per-session selection policy (hello handshake); None = the
    /// context's policy.
    pub policy: Option<String>,
    /// Time-varying offered load; None = closed-loop, as fast as
    /// possible. A `stream:` profile switches the driver to v6 stream
    /// sessions (one per client).
    pub profile: Option<LoadProfile>,
    pub verify: bool,
    pub seed: u64,
    /// v6 (stream profile): per-session latency SLO declared in the
    /// hello/open — drives server-side credit backpressure.
    pub slo_ms: Option<f64>,
    /// v6 (stream profile): windowed-operator width in chunks
    /// (0 = no windowing).
    pub window: usize,
    /// v6 (stream profile): window slide in chunks (0 = tumbling).
    pub slide: usize,
    /// v7: wire framing each connection requests in its hello.
    pub framing: Framing,
    /// v7: open-loop connection fan-out. 0 = off (the closed-loop
    /// `clients` driver). N > 0 opens N concurrent connections as fast
    /// as they can be established, each firing `requests` synchronous
    /// submits — the many-connection soak shape that separates the
    /// epoll transport from thread-per-connection.
    pub connections: usize,
    /// v9: after a successful run, scrape the server's `metrics`
    /// endpoint through a fresh connection and write the snapshot to
    /// this path as a schema-versioned `compar-obs` record
    /// (`compar bench validate` knows the kind).
    pub metrics_out: Option<String>,
}

impl Default for LoadgenOptions {
    fn default() -> LoadgenOptions {
        LoadgenOptions {
            clients: 8,
            requests: 100,
            app: "matmul".into(),
            size: 48,
            tasks: 1,
            ctxs: Vec::new(),
            pipeline: 1,
            policy: None,
            profile: None,
            verify: true,
            seed: 42,
            slo_ms: None,
            window: 0,
            slide: 0,
            framing: Framing::Ndjson,
            connections: 0,
            metrics_out: None,
        }
    }
}

/// Aggregate outcome of one load-generation run (seconds throughout).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub clients: usize,
    pub requests: usize,
    /// Requests in flight per connection during the run.
    pub pipeline: usize,
    pub errors: usize,
    pub elapsed: f64,
    /// Successful requests per second of wall time.
    pub rps: f64,
    pub lat_mean: f64,
    pub lat_min: f64,
    pub lat_max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// variant name -> tasks executed with it.
    pub variants: BTreeMap<String, usize>,
    /// context name -> requests served under it.
    pub per_ctx: BTreeMap<String, usize>,
    /// Requests that shared a codelet batch with at least one other.
    pub batched: usize,
    pub max_rel_err: f64,
    /// v6 (stream profile): windows fired across all streams.
    pub windows: u64,
    /// v6 (stream profile): windows fired at a shed (widened) slide.
    pub shed_windows: u64,
    /// v6 (stream profile): credit-change signals the servers sent
    /// (each one is backpressure engaging or easing).
    pub stream_credits: u64,
    /// v7 (fan-out mode): connections attempted (0 = closed-loop run).
    pub connections: usize,
    /// v7 (fan-out mode): connections that failed to establish or
    /// handshake (each also contributes its requests to `errors`).
    pub connect_failures: usize,
    /// v7 (fan-out mode): median connect+handshake latency (seconds).
    pub connect_p50: f64,
    /// v7 (fan-out mode): p99 connect+handshake latency (seconds).
    pub connect_p99: f64,
}

struct ClientOutcome {
    latencies: Vec<f64>,
    errors: usize,
    variants: BTreeMap<String, usize>,
    per_ctx: BTreeMap<String, usize>,
    batched: usize,
    max_rel_err: f64,
    windows: u64,
    shed_windows: u64,
    stream_credits: u64,
}

impl ClientOutcome {
    fn empty(cap: usize) -> ClientOutcome {
        ClientOutcome {
            latencies: Vec::with_capacity(cap),
            errors: 0,
            variants: BTreeMap::new(),
            per_ctx: BTreeMap::new(),
            batched: 0,
            max_rel_err: 0.0,
            windows: 0,
            shed_windows: 0,
            stream_credits: 0,
        }
    }
}

/// Connection config shared by every driver: the session policy, the
/// declared SLO, and the requested wire framing.
fn client_cfg(opts: &LoadgenOptions) -> ClientConfig {
    ClientConfig {
        policy: opts.policy.clone(),
        slo_ms: opts.slo_ms,
        framing: opts.framing,
        ..ClientConfig::default()
    }
}

fn request_for(opts: &LoadgenOptions, client_idx: usize, r: usize) -> SubmitReq {
    let ctx = if opts.ctxs.is_empty() {
        None
    } else {
        Some(opts.ctxs[(client_idx + r) % opts.ctxs.len()].clone())
    };
    SubmitReq {
        id: r as u64,
        app: opts.app.clone(),
        size: opts.size,
        tasks: opts.tasks,
        ctx,
        seed: opts
            .seed
            .wrapping_add((client_idx as u64) << 20)
            .wrapping_add(r as u64),
        variant: None,
        verify: opts.verify,
        trace: 0,
    }
}

fn tally(out: &mut ClientOutcome, resp: &super::protocol::ResultResp, latency: f64) {
    out.latencies.push(latency);
    for v in &resp.variants {
        *out.variants.entry(v.clone()).or_insert(0) += 1;
    }
    *out.per_ctx.entry(resp.ctx.clone()).or_insert(0) += 1;
    if resp.batch > 1 {
        out.batched += 1;
    }
    out.max_rel_err = out.max_rel_err.max(resp.rel_err);
}

fn drive_client(addr: &str, opts: &LoadgenOptions, client_idx: usize) -> Result<ClientOutcome> {
    let mut c = Client::connect_cfg(addr, &client_cfg(opts))?;
    let mut out = ClientOutcome::empty(opts.requests);
    let window = opts.pipeline.max(1);
    let mut pacer = Pacer::new(opts.profile);
    if window == 1 {
        // synchronous: one outstanding request, honest per-request latency
        for r in 0..opts.requests {
            pacer.wait();
            let req = request_for(opts, client_idx, r);
            let t0 = Instant::now();
            match c.submit(req) {
                Ok(resp) => tally(&mut out, &resp, t0.elapsed().as_secs_f64()),
                Err(_) => out.errors += 1,
            }
        }
    } else {
        // pipelined: keep up to `window` requests in flight; replies may
        // come back out of order, so match them by correlation id. A
        // transport or protocol failure kills this connection only:
        // everything unsent or unanswered counts as an error, matching
        // the synchronous path's keep-going semantics.
        let mut pending: HashMap<u64, Instant> = HashMap::new();
        let mut next = 0usize;
        let mut dead = false;
        while !dead && (next < opts.requests || !pending.is_empty()) {
            while pending.len() < window && next < opts.requests {
                pacer.wait();
                let req = request_for(opts, client_idx, next);
                let id = req.id;
                if c.send_submit(req).is_err() {
                    dead = true;
                    break;
                }
                pending.insert(id, Instant::now());
                next += 1;
            }
            if dead {
                break;
            }
            match c.recv_response() {
                Ok(Response::Result(resp)) => match pending.remove(&resp.id) {
                    Some(t0) => tally(&mut out, &resp, t0.elapsed().as_secs_f64()),
                    None => dead = true, // unsolicited id: protocol confusion
                },
                Ok(Response::Error { id, .. }) => match id {
                    Some(id) => {
                        pending.remove(&id);
                        out.errors += 1;
                    }
                    // an id-less error can't be matched to a pending
                    // request; waiting on would hang forever — give up
                    // on the connection (tail accounting records the
                    // outstanding requests as errors)
                    None => dead = true,
                },
                Ok(_) | Err(_) => dead = true,
            }
        }
        out.errors += pending.len() + opts.requests.saturating_sub(next);
    }
    let _ = c.quit();
    Ok(out)
}

/// Consume one stream event, updating the client's credit window. The
/// server's grant is authoritative: sends are gated on `credit`, so an
/// overloaded server sheds granularity and throttles the offered rate
/// instead of queueing unboundedly.
fn stream_recv_one(
    c: &mut Client,
    out: &mut ClientOutcome,
    credit: &mut u64,
    inflight: &mut u64,
) -> Result<()> {
    match c.recv_response()? {
        Response::StreamAck(a) => {
            out.latencies.push(a.latency);
            for v in &a.variants {
                *out.variants.entry(v.clone()).or_insert(0) += 1;
            }
            *out.per_ctx.entry(a.ctx.clone()).or_insert(0) += 1;
            *credit = a.credit.max(1);
            *inflight = inflight.saturating_sub(1);
        }
        Response::StreamCredit(cr) => {
            *credit = cr.credit.max(1);
            out.stream_credits += 1;
        }
        Response::Error { .. } => {
            out.errors += 1;
            *inflight = inflight.saturating_sub(1);
        }
        other => bail!("unexpected stream response {other:?}"),
    }
    Ok(())
}

/// v6 stream driver: one stream session for this client, chunks offered
/// at the profile rate but gated on the server's credit grant — the
/// honest way to load a backpressured pipeline (offered > sustainable
/// shows up as credit signals and shed windows, not client-side queues).
fn drive_stream_client(
    addr: &str,
    opts: &LoadgenOptions,
    client_idx: usize,
    chunk_kb: usize,
    stages: usize,
) -> Result<ClientOutcome> {
    let mut c = Client::connect_cfg(addr, &client_cfg(opts))?;
    let mut out = ClientOutcome::empty(opts.requests);
    let stream_id = client_idx as u64 + 1;
    // chunk payload: chunk_kb KiB of f32 elements
    let size = (chunk_kb * 1024 / std::mem::size_of::<f32>()).max(1);
    let ctx = if opts.ctxs.is_empty() {
        None
    } else {
        Some(opts.ctxs[client_idx % opts.ctxs.len()].clone())
    };
    let opened = c.stream_open(StreamOpenReq {
        id: stream_id,
        app: opts.app.clone(),
        size,
        stages,
        window: opts.window,
        slide: opts.slide,
        ctx,
        slo_ms: opts.slo_ms,
        trace: 0,
    })?;
    let mut credit = opened.credit.max(1);
    let mut inflight = 0u64;
    let mut pacer = Pacer::new(opts.profile);
    for seq in 0..opts.requests {
        while inflight >= credit {
            stream_recv_one(&mut c, &mut out, &mut credit, &mut inflight)?;
        }
        pacer.wait();
        let seed = opts
            .seed
            .wrapping_add((client_idx as u64) << 20)
            .wrapping_add(seq as u64);
        c.send_stream_chunk(stream_id, seq as u64, seed)?;
        inflight += 1;
    }
    // drain the tail so every ack's latency is tallied before the close
    while inflight > 0 {
        stream_recv_one(&mut c, &mut out, &mut credit, &mut inflight)?;
    }
    let closed = c.stream_close(stream_id)?;
    out.windows = closed.windows;
    out.shed_windows = closed.shed_windows;
    // the server-side count is authoritative (a signal can race the
    // close and be discarded by stream_close's drain)
    out.stream_credits = out.stream_credits.max(closed.credit_signals);
    let _ = c.quit();
    Ok(out)
}

/// One fan-out connection: connect + handshake (timed), then fire the
/// synchronous request burst. A failed connect charges every request
/// it would have sent as an error.
fn drive_fanout_conn(
    addr: &str,
    opts: &LoadgenOptions,
    idx: usize,
) -> (Option<f64>, ClientOutcome) {
    let mut out = ClientOutcome::empty(opts.requests);
    let t0 = Instant::now();
    let mut c = match Client::connect_cfg(addr, &client_cfg(opts)) {
        Ok(c) => c,
        Err(_) => {
            out.errors += opts.requests;
            return (None, out);
        }
    };
    let connect_lat = t0.elapsed().as_secs_f64();
    for r in 0..opts.requests {
        let req = request_for(opts, idx, r);
        let t = Instant::now();
        match c.submit(req) {
            Ok(resp) => tally(&mut out, &resp, t.elapsed().as_secs_f64()),
            Err(_) => out.errors += 1,
        }
    }
    let _ = c.quit();
    (Some(connect_lat), out)
}

/// Open-loop connection fan-out (`--connections N`): N connections are
/// opened concurrently — all at once, not gated on each other — and
/// each runs a synchronous request burst. The interesting numbers are
/// the connect failures and the connect-latency tail: a transport that
/// spawns a thread per connection degrades here long before a
/// readiness loop does.
fn run_fanout(addr: &str, opts: &LoadgenOptions) -> Result<LoadReport> {
    let t0 = Instant::now();
    let results: Vec<(Option<f64>, ClientOutcome)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..opts.connections)
            .map(|i| {
                let addr = addr.to_string();
                let opts = opts.clone();
                s.spawn(move || drive_fanout_conn(&addr, &opts, i))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| {
                    let mut o = ClientOutcome::empty(0);
                    o.errors = opts.requests;
                    (None, o)
                })
            })
            .collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let mut connect_lats: Vec<f64> = Vec::with_capacity(results.len());
    let mut connect_failures = 0usize;
    let mut latencies = Vec::new();
    let mut errors = 0usize;
    let mut variants = BTreeMap::new();
    let mut per_ctx = BTreeMap::new();
    let mut batched = 0usize;
    let mut max_rel_err = 0.0f64;
    for (lat, o) in results {
        match lat {
            Some(l) => connect_lats.push(l),
            None => connect_failures += 1,
        }
        latencies.extend(o.latencies);
        errors += o.errors;
        for (k, v) in o.variants {
            *variants.entry(k).or_insert(0) += v;
        }
        for (k, v) in o.per_ctx {
            *per_ctx.entry(k).or_insert(0) += v;
        }
        batched += o.batched;
        max_rel_err = max_rel_err.max(o.max_rel_err);
    }
    if latencies.is_empty() {
        return Err(anyhow!(
            "no request succeeded ({errors} errors, {connect_failures} connect failures)"
        ));
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    connect_lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = latencies.len();
    Ok(LoadReport {
        clients: opts.connections,
        requests: n + errors,
        pipeline: 1,
        errors,
        elapsed,
        rps: n as f64 / elapsed,
        lat_mean: latencies.iter().sum::<f64>() / n as f64,
        lat_min: latencies[0],
        lat_max: latencies[n - 1],
        p50: stats::percentile(&latencies, 50.0),
        p95: stats::percentile(&latencies, 95.0),
        p99: stats::percentile(&latencies, 99.0),
        variants,
        per_ctx,
        batched,
        max_rel_err,
        windows: 0,
        shed_windows: 0,
        stream_credits: 0,
        connections: opts.connections,
        connect_failures,
        connect_p50: stats::percentile(&connect_lats, 50.0),
        connect_p99: stats::percentile(&connect_lats, 99.0),
    })
}

/// v9: scrape the server's metrics registry right after the drive and
/// write the snapshot to `path` as a schema-versioned `compar-obs`
/// bench record (`compar bench validate` checks it). Scraping through
/// a fresh connection exercises the same v9 `metrics` request any
/// external scraper would use, and recording the loadgen's own success
/// count next to the scrape lets offline tooling reconcile the
/// end-to-end histogram against it.
fn write_metrics_snapshot(
    addr: &str,
    opts: &LoadgenOptions,
    r: &LoadReport,
    path: &str,
) -> Result<()> {
    let mut c = Client::connect_cfg(addr, &client_cfg(opts))?;
    let m = c.metrics(None)?;
    let _ = c.quit();
    let mut rec = std::collections::BTreeMap::new();
    rec.insert("bench".into(), Json::Str("compar-obs".into()));
    rec.insert("status".into(), Json::Str("measured".into()));
    rec.insert(
        "schema".into(),
        Json::Num(crate::bench_harness::serve_bench::BENCH_SCHEMA as f64),
    );
    rec.insert("requests".into(), Json::Num(r.requests as f64));
    rec.insert(
        "requests_ok".into(),
        Json::Num(r.requests.saturating_sub(r.errors) as f64),
    );
    rec.insert("metrics".into(), m.metrics);
    let text = crate::util::json::to_string(&Json::Obj(rec));
    std::fs::write(path, text + "\n")
        .with_context(|| format!("writing metrics snapshot {path}"))?;
    Ok(())
}

/// Run the load against a listening server.
pub fn run(addr: &str, opts: &LoadgenOptions) -> Result<LoadReport> {
    let report = run_drivers(addr, opts)?;
    if let Some(path) = &opts.metrics_out {
        write_metrics_snapshot(addr, opts, &report, path)?;
    }
    Ok(report)
}

fn run_drivers(addr: &str, opts: &LoadgenOptions) -> Result<LoadReport> {
    if opts.connections > 0 {
        if opts.requests == 0 {
            return Err(anyhow!("need at least one request per connection"));
        }
        return run_fanout(addr, opts);
    }
    if opts.clients == 0 || opts.requests == 0 {
        return Err(anyhow!("need at least one client and one request"));
    }
    let stream_shape = match opts.profile {
        Some(LoadProfile::Stream {
            chunk_kb, stages, ..
        }) => Some((chunk_kb, stages)),
        _ => None,
    };
    let t0 = Instant::now();
    let outcomes: Vec<Result<ClientOutcome>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..opts.clients)
            .map(|i| {
                let addr = addr.to_string();
                let opts = opts.clone();
                s.spawn(move || match stream_shape {
                    Some((kb, st)) => drive_stream_client(&addr, &opts, i, kb, st),
                    None => drive_client(&addr, &opts, i),
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("client thread panicked")))
            })
            .collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let mut latencies = Vec::new();
    let mut errors = 0usize;
    let mut variants = BTreeMap::new();
    let mut per_ctx = BTreeMap::new();
    let mut batched = 0usize;
    let mut max_rel_err = 0.0f64;
    let mut windows = 0u64;
    let mut shed_windows = 0u64;
    let mut stream_credits = 0u64;
    for o in outcomes {
        let o = o?;
        latencies.extend(o.latencies);
        errors += o.errors;
        for (k, v) in o.variants {
            *variants.entry(k).or_insert(0) += v;
        }
        for (k, v) in o.per_ctx {
            *per_ctx.entry(k).or_insert(0) += v;
        }
        batched += o.batched;
        max_rel_err = max_rel_err.max(o.max_rel_err);
        windows += o.windows;
        shed_windows += o.shed_windows;
        stream_credits += o.stream_credits;
    }
    if latencies.is_empty() {
        return Err(anyhow!("no request succeeded ({errors} errors)"));
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = latencies.len();
    Ok(LoadReport {
        clients: opts.clients,
        requests: n + errors,
        pipeline: opts.pipeline.max(1),
        errors,
        elapsed,
        rps: n as f64 / elapsed,
        lat_mean: latencies.iter().sum::<f64>() / n as f64,
        lat_min: latencies[0],
        lat_max: latencies[n - 1],
        p50: stats::percentile(&latencies, 50.0),
        p95: stats::percentile(&latencies, 95.0),
        p99: stats::percentile(&latencies, 99.0),
        variants,
        per_ctx,
        batched,
        max_rel_err,
        windows,
        shed_windows,
        stream_credits,
        connections: 0,
        connect_failures: 0,
        connect_p50: 0.0,
        connect_p99: 0.0,
    })
}

/// Plain-text report.
pub fn render(r: &LoadReport) -> String {
    let mut out = String::new();
    out.push_str("== compar loadgen report ==\n");
    out.push_str(&format!(
        "clients {}  requests {}  pipeline {}  errors {}  elapsed {:.3} s\n",
        r.clients, r.requests, r.pipeline, r.errors, r.elapsed
    ));
    out.push_str(&format!("throughput {:.1} req/s\n", r.rps));
    out.push_str(&format!(
        "latency mean {}  min {}  max {}\n",
        stats::fmt_time(r.lat_mean),
        stats::fmt_time(r.lat_min),
        stats::fmt_time(r.lat_max)
    ));
    out.push_str(&format!(
        "latency p50 {}  p95 {}  p99 {}\n",
        stats::fmt_time(r.p50),
        stats::fmt_time(r.p95),
        stats::fmt_time(r.p99)
    ));
    if !r.per_ctx.is_empty() {
        let cells: Vec<String> = r
            .per_ctx
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        out.push_str(&format!("requests per context: {}\n", cells.join("  ")));
    }
    if !r.variants.is_empty() {
        let cells: Vec<String> = r
            .variants
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        out.push_str(&format!("variant selection: {}\n", cells.join("  ")));
    }
    out.push_str(&format!(
        "batched requests {}  max rel L2 err {:.2e}\n",
        r.batched, r.max_rel_err
    ));
    if r.windows > 0 || r.stream_credits > 0 {
        out.push_str(&format!(
            "stream windows {} ({} shed)  credit signals {}\n",
            r.windows, r.shed_windows, r.stream_credits
        ));
    }
    if r.connections > 0 {
        out.push_str(&format!(
            "connections {}  connect failures {}  connect p50 {}  p99 {}\n",
            r.connections,
            r.connect_failures,
            stats::fmt_time(r.connect_p50),
            stats::fmt_time(r.connect_p99)
        ));
    }
    out
}

/// JSON form (BENCH_serve.json baseline record).
pub fn to_json(r: &LoadReport) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("clients".into(), Json::Num(r.clients as f64));
    m.insert("requests".into(), Json::Num(r.requests as f64));
    m.insert("pipeline".into(), Json::Num(r.pipeline as f64));
    m.insert("errors".into(), Json::Num(r.errors as f64));
    m.insert("elapsed_s".into(), Json::Num(r.elapsed));
    m.insert("rps".into(), Json::Num(r.rps));
    m.insert("lat_mean_s".into(), Json::Num(r.lat_mean));
    m.insert("lat_min_s".into(), Json::Num(r.lat_min));
    m.insert("lat_max_s".into(), Json::Num(r.lat_max));
    m.insert("p50_s".into(), Json::Num(r.p50));
    m.insert("p95_s".into(), Json::Num(r.p95));
    m.insert("p99_s".into(), Json::Num(r.p99));
    m.insert("batched".into(), Json::Num(r.batched as f64));
    m.insert("max_rel_err".into(), Json::Num(r.max_rel_err));
    m.insert("windows".into(), Json::Num(r.windows as f64));
    m.insert("shed_windows".into(), Json::Num(r.shed_windows as f64));
    m.insert("stream_credits".into(), Json::Num(r.stream_credits as f64));
    m.insert("connections".into(), Json::Num(r.connections as f64));
    m.insert(
        "connect_failures".into(),
        Json::Num(r.connect_failures as f64),
    );
    m.insert("connect_p50_s".into(), Json::Num(r.connect_p50));
    m.insert("connect_p99_s".into(), Json::Num(r.connect_p99));
    let mut variants = std::collections::BTreeMap::new();
    for (k, v) in &r.variants {
        variants.insert(k.clone(), Json::Num(*v as f64));
    }
    m.insert("variants".into(), Json::Obj(variants));
    let mut per_ctx = std::collections::BTreeMap::new();
    for (k, v) in &r.per_ctx {
        per_ctx.insert(k.clone(), Json::Num(*v as f64));
    }
    m.insert("per_ctx".into(), Json::Obj(per_ctx));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_profile_parses_and_phases() {
        let p = LoadProfile::parse("burst:40:2:300").unwrap();
        assert_eq!(
            p,
            LoadProfile::Burst {
                high: 40.0,
                low: 2.0,
                period_ms: 300
            }
        );
        assert_eq!(p.name(), "burst:40:2:300");
        // phase 0 is high, phase 1 low, phase 2 high again
        assert_eq!(p.rate_at(Duration::from_millis(0)), 40.0);
        assert_eq!(p.rate_at(Duration::from_millis(299)), 40.0);
        assert_eq!(p.rate_at(Duration::from_millis(300)), 2.0);
        assert_eq!(p.rate_at(Duration::from_millis(650)), 40.0);
    }

    #[test]
    fn burst_profile_rejects_malformed() {
        assert!(LoadProfile::parse("burst:40:2").is_err());
        assert!(LoadProfile::parse("burst:0:2:300").is_err());
        assert!(LoadProfile::parse("burst:40:-1:300").is_err());
        assert!(LoadProfile::parse("burst:40:2:0").is_err());
        assert!(LoadProfile::parse("burst:40:2:-300").is_err());
        assert!(LoadProfile::parse("ramp:1:2:3").is_err());
        assert!(LoadProfile::parse("burst:x:2:300").is_err());
    }

    #[test]
    fn burst_rate_at_survives_zero_period() {
        // parse() rejects period 0, but direct construction must not
        // divide by zero — the degenerate shape pins to the high phase
        let p = LoadProfile::Burst {
            high: 10.0,
            low: 1.0,
            period_ms: 0,
        };
        assert_eq!(p.rate_at(Duration::from_millis(0)), 10.0);
        assert_eq!(p.rate_at(Duration::from_millis(12345)), 10.0);
    }

    #[test]
    fn stream_profile_parses() {
        let p = LoadProfile::parse("stream:120:64:2").unwrap();
        assert_eq!(
            p,
            LoadProfile::Stream {
                rate: 120.0,
                chunk_kb: 64,
                stages: 2
            }
        );
        assert_eq!(p.name(), "stream:120:64:2");
        // constant offered rate, no phases
        assert_eq!(p.rate_at(Duration::from_millis(0)), 120.0);
        assert_eq!(p.rate_at(Duration::from_secs(9)), 120.0);
    }

    #[test]
    fn stream_profile_rejects_malformed() {
        assert!(LoadProfile::parse("stream:0:64:2").is_err());
        assert!(LoadProfile::parse("stream:-5:64:2").is_err());
        assert!(LoadProfile::parse("stream:120:0:2").is_err());
        assert!(LoadProfile::parse("stream:120:-64:2").is_err());
        assert!(LoadProfile::parse("stream:120:64:0").is_err());
        assert!(LoadProfile::parse("stream:120:64").is_err());
    }
}
