//! Transport layer for the serve wire protocol: framing codecs,
//! pooled buffers, and a readiness-driven event loop.
//!
//! The serve stack historically ran one blocking thread per connection
//! with line-delimited JSON. This module factors the wire concerns out
//! of the session logic so the same protocol state machine can run on
//! either of two transports:
//!
//! - **threads** — the classic blocking path (one session thread per
//!   connection), kept as the default for debuggability and tests;
//! - **epoll** — a readiness-driven event loop (epoll(7) on Linux via
//!   a thin FFI shim, portable poll(2) everywhere else) multiplexing
//!   thousands of non-blocking sessions on one thread.
//!
//! Orthogonally, each session negotiates a *framing* in `hello`
//! (protocol v7): newline-delimited JSON (the default, debuggable with
//! `nc`) or a compact length-prefixed binary encoding of the same
//! message values. Both transports speak both framings; the decoder
//! ([`codec::FrameDecoder`]) and encoder ([`codec::encode_frame`]) are
//! pure functions over byte buffers shared by every path, including
//! the cluster router's backend connections.

pub mod buffer;
pub mod codec;
#[cfg(unix)]
pub mod event_loop;
#[cfg(unix)]
pub mod poller;

pub use buffer::BufferPool;
pub use codec::{encode_frame, FrameDecoder, Framing};

use anyhow::{bail, Result};

/// Which connection transport the server runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum TransportKind {
    /// One blocking thread per connection (the historical path).
    #[default]
    Threads,
    /// Readiness event loop: epoll on Linux, poll(2) fallback.
    Epoll,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s {
            "threads" | "thread" | "blocking" => Ok(TransportKind::Threads),
            "epoll" | "poll" | "event" => Ok(TransportKind::Epoll),
            other => bail!("unknown transport '{other}' (expected epoll|threads)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Threads => "threads",
            TransportKind::Epoll => "epoll",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("threads").unwrap(), TransportKind::Threads);
        assert_eq!(TransportKind::parse("epoll").unwrap(), TransportKind::Epoll);
        assert!(TransportKind::parse("uring").is_err());
        assert_eq!(TransportKind::default().name(), "threads");
    }
}
