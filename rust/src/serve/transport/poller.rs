//! Readiness polling behind one small API: epoll(7) on Linux via a
//! thin hand-rolled FFI shim (std already links libc; no new crates),
//! with a portable poll(2) fallback for every other unix.
//!
//! The event loop only needs four operations — register, modify,
//! deregister, wait — with a `u64` token per fd and a single "also
//! watch writable" bit (readable interest is implicit: every
//! registered fd is a connection we are reading from).

use std::io;
use std::os::raw::c_int;
use std::os::unix::io::RawFd;

use anyhow::{bail, Context, Result};

/// One readiness report from `wait`.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Peer hung up or the fd errored; treat as readable-to-EOF.
    pub hangup: bool,
}

pub enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(EpollPoller),
    Poll(PollPoller),
}

impl Poller {
    /// Best poller for this platform: epoll on Linux (falling back to
    /// poll(2) if epoll_create1 fails), poll(2) elsewhere.
    pub fn new_best() -> Poller {
        #[cfg(target_os = "linux")]
        {
            if let Ok(p) = EpollPoller::new() {
                return Poller::Epoll(p);
            }
        }
        Poller::Poll(PollPoller::new())
    }

    pub fn kind(&self) -> &'static str {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(_) => "epoll",
            Poller::Poll(_) => "poll",
        }
    }

    pub fn register(&mut self, fd: RawFd, token: u64, writable: bool) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(sys_epoll::EPOLL_CTL_ADD, fd, token, writable),
            Poller::Poll(p) => p.register(fd, token, writable),
        }
    }

    pub fn modify(&mut self, fd: RawFd, token: u64, writable: bool) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(sys_epoll::EPOLL_CTL_MOD, fd, token, writable),
            Poller::Poll(p) => p.modify(fd, writable),
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(sys_epoll::EPOLL_CTL_DEL, fd, 0, false),
            Poller::Poll(p) => p.deregister(fd),
        }
    }

    /// Block up to `timeout_ms` (-1 = forever) and append readiness
    /// events to `out`. A signal interruption returns with no events.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(out, timeout_ms),
            Poller::Poll(p) => p.wait(out, timeout_ms),
        }
    }
}

// ---------------------------------------------------------------- epoll (linux)

#[cfg(target_os = "linux")]
mod sys_epoll {
    use std::os::raw::c_int;

    // On x86 the kernel's struct epoll_event is packed; elsewhere it
    // has natural alignment. Mirror glibc's definition exactly.
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86_64", target_arch = "x86"), repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
}

#[cfg(target_os = "linux")]
pub struct EpollPoller {
    epfd: RawFd,
    scratch: Vec<sys_epoll::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    pub fn new() -> Result<EpollPoller> {
        let epfd = unsafe { sys_epoll::epoll_create1(sys_epoll::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error()).context("epoll_create1");
        }
        Ok(EpollPoller {
            epfd,
            scratch: vec![sys_epoll::EpollEvent { events: 0, data: 0 }; 1024],
        })
    }

    fn ctl(&mut self, op: c_int, fd: RawFd, token: u64, writable: bool) -> Result<()> {
        let mut interest = sys_epoll::EPOLLIN | sys_epoll::EPOLLRDHUP;
        if writable {
            interest |= sys_epoll::EPOLLOUT;
        }
        let mut ev = sys_epoll::EpollEvent {
            events: interest,
            data: token,
        };
        let rc = unsafe { sys_epoll::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error())
                .with_context(|| format!("epoll_ctl op={op} fd={fd}"));
        }
        Ok(())
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> Result<()> {
        let n = unsafe {
            sys_epoll::epoll_wait(
                self.epfd,
                self.scratch.as_mut_ptr(),
                self.scratch.len() as c_int,
                timeout_ms,
            )
        };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            bail!("epoll_wait: {err}");
        }
        for i in 0..n as usize {
            // Copy out of the (possibly packed) kernel struct by value.
            let raw = self.scratch[i];
            let events = raw.events;
            let token = raw.data;
            out.push(Event {
                token,
                readable: events & (sys_epoll::EPOLLIN | sys_epoll::EPOLLRDHUP) != 0,
                writable: events & sys_epoll::EPOLLOUT != 0,
                hangup: events & (sys_epoll::EPOLLERR | sys_epoll::EPOLLHUP) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollPoller {
    fn drop(&mut self) {
        unsafe { sys_epoll::close(self.epfd) };
    }
}

// ---------------------------------------------------------------- poll(2) fallback

mod sys_poll {
    use std::os::raw::{c_int, c_short};

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    #[cfg(target_os = "linux")]
    pub type Nfds = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    pub type Nfds = std::os::raw::c_uint;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
    }

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;
}

#[derive(Default)]
pub struct PollPoller {
    fds: Vec<sys_poll::PollFd>,
    tokens: Vec<u64>,
}

impl PollPoller {
    pub fn new() -> PollPoller {
        PollPoller::default()
    }

    fn events_for(writable: bool) -> std::os::raw::c_short {
        if writable {
            sys_poll::POLLIN | sys_poll::POLLOUT
        } else {
            sys_poll::POLLIN
        }
    }

    fn register(&mut self, fd: RawFd, token: u64, writable: bool) -> Result<()> {
        self.fds.push(sys_poll::PollFd {
            fd,
            events: Self::events_for(writable),
            revents: 0,
        });
        self.tokens.push(token);
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, writable: bool) -> Result<()> {
        match self.fds.iter_mut().find(|p| p.fd == fd) {
            Some(p) => {
                p.events = Self::events_for(writable);
                Ok(())
            }
            None => bail!("poll modify: fd {fd} not registered"),
        }
    }

    fn deregister(&mut self, fd: RawFd) -> Result<()> {
        match self.fds.iter().position(|p| p.fd == fd) {
            Some(i) => {
                self.fds.swap_remove(i);
                self.tokens.swap_remove(i);
                Ok(())
            }
            None => bail!("poll deregister: fd {fd} not registered"),
        }
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> Result<()> {
        if self.fds.is_empty() {
            // Nothing registered: emulate the timeout so callers still
            // get their periodic drain checks.
            if timeout_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(timeout_ms as u64));
            }
            return Ok(());
        }
        let n = unsafe {
            sys_poll::poll(
                self.fds.as_mut_ptr(),
                self.fds.len() as sys_poll::Nfds,
                timeout_ms,
            )
        };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            bail!("poll: {err}");
        }
        for (i, p) in self.fds.iter().enumerate() {
            if p.revents == 0 {
                continue;
            }
            out.push(Event {
                token: self.tokens[i],
                readable: p.revents & sys_poll::POLLIN != 0,
                writable: p.revents & sys_poll::POLLOUT != 0,
                hangup: p.revents & (sys_poll::POLLERR | sys_poll::POLLHUP) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    // Exercise both backends against a real socketpair: writable on
    // registration, readable once bytes land, deregister stops events.
    fn exercise(mut poller: Poller) {
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        poller.register(b.as_raw_fd(), 7, true).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, 100).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.writable),
            "fresh socket reports writable ({})",
            poller.kind()
        );

        poller.modify(b.as_raw_fd(), 7, false).unwrap();
        a.write_all(b"hi").unwrap();
        events.clear();
        poller.wait(&mut events, 1000).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.readable),
            "pending bytes report readable ({})",
            poller.kind()
        );

        poller.deregister(b.as_raw_fd()).unwrap();
        events.clear();
        poller.wait(&mut events, 10).unwrap();
        assert!(events.is_empty(), "deregistered fd stays silent");
    }

    #[test]
    fn poll_backend_reports_readiness() {
        exercise(Poller::Poll(PollPoller::new()));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_backend_reports_readiness() {
        exercise(Poller::Epoll(EpollPoller::new().unwrap()));
    }

    #[test]
    fn best_poller_exists() {
        let p = Poller::new_best();
        assert!(!p.kind().is_empty());
    }
}
