//! Pooled byte buffers: a freelist of `Vec<u8>` so steady-state
//! serving does no per-message allocation.
//!
//! Every hot path that needs scratch bytes — per-connection read
//! accumulation, response frame encoding, the event loop's outbound
//! queues — takes a buffer from the pool and returns it when the bytes
//! are on the wire. Buffers keep their capacity across cycles, so
//! after warm-up the allocator is out of the per-message picture.
//! Hit/miss counters are exposed for tests and diagnostics.
//!
//! The freelist is bounded two ways: by entry count (`max_pooled`) and
//! by a **byte high-water mark**. Buffers may legitimately grow up to
//! 8× the chunk size before they count as outliers, so a connection
//! burst that returns hundreds of grown buffers could otherwise pin
//! `max_pooled × 8 × chunk` bytes long after the burst drains. When a
//! returned buffer would push the pooled bytes past the mark, the
//! largest pooled buffers are dropped first until it fits — peak
//! memory tracks the *steady* working set, not the worst burst.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub struct BufferPool {
    /// Freelist cap: beyond this, returned buffers are dropped.
    max_pooled: usize,
    /// Capacity fresh buffers are created with.
    chunk: usize,
    /// Byte high-water mark: pooled capacities never sum past this.
    max_bytes: usize,
    free: Mutex<Vec<Vec<u8>>>,
    /// Sum of the pooled buffers' capacities (tracked under `free`'s
    /// lock; atomic only so `pooled_bytes()` needs no lock).
    bytes: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl BufferPool {
    pub fn new(max_pooled: usize, chunk: usize) -> BufferPool {
        // default mark: every slot at its nominal chunk size plus 2x
        // headroom for grown-but-kept buffers — far below the 8x worst
        // case the per-buffer outlier check alone would allow
        BufferPool::with_byte_cap(
            max_pooled,
            chunk,
            max_pooled.saturating_mul(chunk).saturating_mul(2),
        )
    }

    /// A pool with an explicit byte high-water mark.
    pub fn with_byte_cap(max_pooled: usize, chunk: usize, max_bytes: usize) -> BufferPool {
        BufferPool {
            max_pooled,
            chunk,
            max_bytes,
            free: Mutex::new(Vec::new()),
            bytes: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A serving-shaped default: enough pooled buffers for a deep
    /// outbound queue plus per-connection read sides.
    pub fn serving_default() -> BufferPool {
        BufferPool::new(1024, 16 * 1024)
    }

    /// Take a cleared buffer, reusing a pooled one when available.
    pub fn take(&self) -> Vec<u8> {
        if let Some(mut b) = self.free.lock().unwrap().pop() {
            self.bytes
                .fetch_sub(b.capacity() as u64, Ordering::Relaxed);
            b.clear();
            self.hits.fetch_add(1, Ordering::Relaxed);
            return b;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(self.chunk)
    }

    /// Return a buffer to the freelist. Zero-capacity buffers and
    /// outliers that ballooned past 8× the chunk size are dropped so
    /// one giant frame can't pin memory forever; when the byte
    /// high-water mark would be crossed, the largest pooled buffers
    /// are evicted first to make room.
    pub fn put(&self, mut b: Vec<u8>) {
        if b.capacity() == 0 || b.capacity() > self.chunk * 8 || b.capacity() > self.max_bytes {
            return;
        }
        b.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() >= self.max_pooled {
            return;
        }
        let mut pooled = self.bytes.load(Ordering::Relaxed) as usize;
        if pooled + b.capacity() > self.max_bytes {
            // evict largest-first: one eviction frees the most room,
            // and the small steady-state buffers stay warm
            free.sort_unstable_by_key(Vec::capacity);
            while pooled + b.capacity() > self.max_bytes {
                let Some(victim) = free.pop() else { break };
                pooled -= victim.capacity();
                self.bytes
                    .fetch_sub(victim.capacity() as u64, Ordering::Relaxed);
            }
            if pooled + b.capacity() > self.max_bytes {
                return;
            }
        }
        self.bytes.fetch_add(b.capacity() as u64, Ordering::Relaxed);
        free.push(b);
    }

    /// Sum of the pooled buffers' capacities — bounded by the byte
    /// high-water mark at all times.
    pub fn pooled_bytes(&self) -> usize {
        self.bytes.load(Ordering::Relaxed) as usize
    }

    /// (hits, misses) — a warm steady state shows hits climbing while
    /// misses stay flat.
    pub fn counters(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_capacity() {
        let pool = BufferPool::new(4, 64);
        let mut b = pool.take();
        b.extend_from_slice(&[7u8; 40]);
        let cap = b.capacity();
        pool.put(b);
        assert_eq!(pool.pooled_bytes(), cap);
        let b2 = pool.take();
        assert_eq!(b2.len(), 0, "pooled buffers come back cleared");
        assert_eq!(b2.capacity(), cap, "capacity survives the cycle");
        assert_eq!(pool.pooled_bytes(), 0);
        let (hits, misses) = pool.counters();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn pool_drops_outliers_and_respects_cap() {
        let pool = BufferPool::new(1, 64);
        pool.put(Vec::with_capacity(64 * 16)); // outlier: dropped
        assert_eq!(pool.free.lock().unwrap().len(), 0);
        pool.put(Vec::with_capacity(64));
        pool.put(Vec::with_capacity(64)); // over freelist cap: dropped
        assert_eq!(pool.free.lock().unwrap().len(), 1);
    }

    #[test]
    fn byte_high_water_mark_bounds_a_burst_of_grown_buffers() {
        // 8 slots of nominal 64 B, but only 256 B pooled: a burst of
        // grown (4x chunk) returns must not pin 8 x 256 B
        let pool = BufferPool::with_byte_cap(8, 64, 256);
        for _ in 0..8 {
            pool.put(Vec::with_capacity(256)); // within the 8x outlier bound
        }
        assert!(
            pool.pooled_bytes() <= 256,
            "burst pinned {} B past the 256 B mark",
            pool.pooled_bytes()
        );
        assert_eq!(pool.free.lock().unwrap().len(), 1);
    }

    #[test]
    fn byte_cap_evicts_largest_first_keeping_steady_state_warm() {
        let pool = BufferPool::with_byte_cap(8, 64, 320);
        pool.put(Vec::with_capacity(64));
        pool.put(Vec::with_capacity(256)); // a grown burst survivor
        assert_eq!(pool.pooled_bytes(), 320);
        // the next small return must evict the 256 B outlier, not be
        // refused (and not evict the warm 64 B steady-state buffer)
        pool.put(Vec::with_capacity(64));
        let caps: Vec<usize> = pool
            .free
            .lock()
            .unwrap()
            .iter()
            .map(Vec::capacity)
            .collect();
        assert_eq!(caps, vec![64, 64]);
        assert_eq!(pool.pooled_bytes(), 128);
    }

    #[test]
    fn count_and_byte_caps_compose() {
        // count cap still applies even with byte headroom to spare
        let pool = BufferPool::with_byte_cap(2, 64, 4096);
        for _ in 0..4 {
            pool.put(Vec::with_capacity(64));
        }
        assert_eq!(pool.free.lock().unwrap().len(), 2);
        assert_eq!(pool.pooled_bytes(), 128);
    }
}
