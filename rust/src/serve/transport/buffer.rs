//! Pooled byte buffers: a freelist of `Vec<u8>` so steady-state
//! serving does no per-message allocation.
//!
//! Every hot path that needs scratch bytes — per-connection read
//! accumulation, response frame encoding, the event loop's outbound
//! queues — takes a buffer from the pool and returns it when the bytes
//! are on the wire. Buffers keep their capacity across cycles, so
//! after warm-up the allocator is out of the per-message picture.
//! Hit/miss counters are exposed for tests and diagnostics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub struct BufferPool {
    /// Freelist cap: beyond this, returned buffers are dropped.
    max_pooled: usize,
    /// Capacity fresh buffers are created with.
    chunk: usize,
    free: Mutex<Vec<Vec<u8>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl BufferPool {
    pub fn new(max_pooled: usize, chunk: usize) -> BufferPool {
        BufferPool {
            max_pooled,
            chunk,
            free: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A serving-shaped default: enough pooled buffers for a deep
    /// outbound queue plus per-connection read sides.
    pub fn serving_default() -> BufferPool {
        BufferPool::new(1024, 16 * 1024)
    }

    /// Take a cleared buffer, reusing a pooled one when available.
    pub fn take(&self) -> Vec<u8> {
        if let Some(mut b) = self.free.lock().unwrap().pop() {
            b.clear();
            self.hits.fetch_add(1, Ordering::Relaxed);
            return b;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(self.chunk)
    }

    /// Return a buffer to the freelist. Zero-capacity buffers and
    /// outliers that ballooned past 8× the chunk size are dropped so
    /// one giant frame can't pin memory forever.
    pub fn put(&self, mut b: Vec<u8>) {
        if b.capacity() == 0 || b.capacity() > self.chunk * 8 {
            return;
        }
        b.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_pooled {
            free.push(b);
        }
    }

    /// (hits, misses) — a warm steady state shows hits climbing while
    /// misses stay flat.
    pub fn counters(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_capacity() {
        let pool = BufferPool::new(4, 64);
        let mut b = pool.take();
        b.extend_from_slice(&[7u8; 40]);
        let cap = b.capacity();
        pool.put(b);
        let b2 = pool.take();
        assert_eq!(b2.len(), 0, "pooled buffers come back cleared");
        assert_eq!(b2.capacity(), cap, "capacity survives the cycle");
        let (hits, misses) = pool.counters();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn pool_drops_outliers_and_respects_cap() {
        let pool = BufferPool::new(1, 64);
        pool.put(Vec::with_capacity(64 * 16)); // outlier: dropped
        assert_eq!(pool.free.lock().unwrap().len(), 0);
        pool.put(Vec::with_capacity(64));
        pool.put(Vec::with_capacity(64)); // over freelist cap: dropped
        assert_eq!(pool.free.lock().unwrap().len(), 1);
    }
}
