//! Event-loop plumbing shared by the multiplexed serve transport: a
//! cross-thread waker, a dirty-connection hub, and per-connection
//! outbound queues with vectored, coalescing flushes.
//!
//! The loop thread owns every connection socket; completion threads,
//! stream workers, and the dispatcher never touch a socket directly.
//! They encode a frame into a pooled buffer, enqueue it on the
//! connection's [`Outbox`], and ring the [`WakeHub`] — the loop then
//! drains each dirty outbox with a single `writev`-style vectored
//! write per readiness cycle, so a batch completion's worth of
//! results (or an ack + credit pair) costs one syscall, not N.

use std::collections::VecDeque;
use std::io::{self, IoSlice, Read, Write};
use std::os::unix::net::UnixStream;
use std::sync::{Arc, Mutex};

use super::buffer::BufferPool;

/// Most frames batched into one vectored write (IOV_MAX headroom).
const MAX_IOVS: usize = 64;

/// Write half of the loop's self-wake channel. Nonblocking: a full
/// pipe already guarantees a pending wake, so `wake` never blocks —
/// which is what makes it safe to call while the loop itself is
/// stalled in a blocking admission acquire.
pub struct Waker {
    tx: UnixStream,
}

impl Waker {
    /// Build the wake channel; the returned stream is the read half,
    /// to be registered (nonblocking) in the poller.
    pub fn pair() -> io::Result<(Waker, UnixStream)> {
        let (tx, rx) = UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok((Waker { tx }, rx))
    }

    pub fn wake(&self) {
        // WouldBlock means the pipe is already full of wakes: fine.
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// Drain all pending wake bytes (the loop calls this on readability).
pub fn drain_wakes(rx: &mut UnixStream) {
    let mut sink = [0u8; 256];
    while matches!(rx.read(&mut sink), Ok(n) if n > 0) {}
}

/// Wake fan-in: producers note which connection has pending output,
/// the loop drains the set each cycle.
pub struct WakeHub {
    waker: Waker,
    dirty: Mutex<Vec<u64>>,
}

impl WakeHub {
    pub fn new(waker: Waker) -> WakeHub {
        WakeHub {
            waker,
            dirty: Mutex::new(Vec::new()),
        }
    }

    pub fn notify(&self, token: u64) {
        self.dirty.lock().unwrap().push(token);
        self.waker.wake();
    }

    /// Move the dirty set into `out` (deduplicated, order-preserving
    /// enough: tokens are deduped after sort by the caller's map).
    pub fn drain(&self, out: &mut Vec<u64>) {
        let mut d = self.dirty.lock().unwrap();
        out.append(&mut d);
    }
}

struct OutboxInner {
    frames: VecDeque<Vec<u8>>,
    /// Bytes of the front frame already written (short-write cursor).
    head: usize,
    closed: bool,
}

/// One connection's outbound frame queue. Thread-safe producer side
/// (`send`), loop-owned consumer side (`flush`). Frames come from and
/// return to the shared [`BufferPool`].
pub struct Outbox {
    token: u64,
    inner: Mutex<OutboxInner>,
    hub: Arc<WakeHub>,
    pool: Arc<BufferPool>,
}

impl Outbox {
    pub fn new(token: u64, hub: Arc<WakeHub>, pool: Arc<BufferPool>) -> Arc<Outbox> {
        Arc::new(Outbox {
            token,
            inner: Mutex::new(OutboxInner {
                frames: VecDeque::new(),
                head: 0,
                closed: false,
            }),
            hub,
            pool,
        })
    }

    pub fn token(&self) -> u64 {
        self.token
    }

    /// The buffer pool frames are drawn from and recycled to.
    pub fn pool(&self) -> &BufferPool {
        &self.pool
    }

    /// Queue one encoded frame and wake the loop. Returns false (and
    /// recycles the buffer) if the connection is already closed.
    pub fn send(&self, frame: Vec<u8>) -> bool {
        if frame.is_empty() {
            self.pool.put(frame);
            return true;
        }
        {
            let mut q = self.inner.lock().unwrap();
            if q.closed {
                drop(q);
                self.pool.put(frame);
                return false;
            }
            q.frames.push_back(frame);
        }
        self.hub.notify(self.token);
        true
    }

    /// Mark closed and recycle anything still queued. Late completions
    /// for a dead client become no-ops, mirroring the blocking path's
    /// "a dead client is not a server error" stance.
    pub fn close(&self) {
        let mut q = self.inner.lock().unwrap();
        q.closed = true;
        q.head = 0;
        while let Some(f) = q.frames.pop_front() {
            self.pool.put(f);
        }
    }

    pub fn pending(&self) -> bool {
        !self.inner.lock().unwrap().frames.is_empty()
    }

    /// Write as much queued output as `w` accepts, coalescing up to
    /// [`MAX_IOVS`] frames per vectored write. Returns `Ok(true)` when
    /// fully drained, `Ok(false)` when the writer would block with
    /// bytes still queued (caller arms writable interest), `Err` on a
    /// dead peer. Partial writes resume from the exact byte offset.
    pub fn flush(&self, w: &mut impl Write) -> io::Result<bool> {
        loop {
            let mut q = self.inner.lock().unwrap();
            if q.frames.is_empty() {
                return Ok(true);
            }
            let head = q.head;
            let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(q.frames.len().min(MAX_IOVS));
            for (i, f) in q.frames.iter().take(MAX_IOVS).enumerate() {
                if i == 0 {
                    slices.push(IoSlice::new(&f[head..]));
                } else {
                    slices.push(IoSlice::new(f));
                }
            }
            let wrote = match w.write_vectored(&slices) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            let mut left = wrote;
            while left > 0 {
                let front_rem = q.frames[0].len() - q.head;
                if left >= front_rem {
                    left -= front_rem;
                    q.head = 0;
                    let done = q.frames.pop_front().expect("front frame");
                    self.pool.put(done);
                } else {
                    q.head += left;
                    left = 0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Writer that accepts at most `cap` bytes per call — exercises
    /// the short-write resumption cursor.
    struct Trickle {
        out: Vec<u8>,
        cap: usize,
    }

    impl Write for Trickle {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            let n = buf.len().min(self.cap);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        // write_vectored's default impl forwards the first nonempty
        // slice to write(), which is exactly the trickle we want.
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    fn outbox() -> (Arc<Outbox>, Arc<BufferPool>) {
        let (waker, _rx) = Waker::pair().unwrap();
        let hub = Arc::new(WakeHub::new(waker));
        let pool = Arc::new(BufferPool::new(16, 64));
        (Outbox::new(3, hub, pool.clone()), pool)
    }

    #[test]
    fn flush_resumes_after_short_writes() {
        let (ob, _pool) = outbox();
        ob.send(b"hello ".to_vec());
        ob.send(b"coalesced ".to_vec());
        ob.send(b"world".to_vec());
        let mut w = Trickle {
            out: Vec::new(),
            cap: 4,
        };
        assert!(ob.flush(&mut w).unwrap());
        assert_eq!(w.out, b"hello coalesced world");
        assert!(!ob.pending());
    }

    #[test]
    fn flush_reports_wouldblock_and_resumes() {
        struct Blocky {
            out: Vec<u8>,
            budget: usize,
        }
        impl Write for Blocky {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                if self.budget == 0 {
                    return Err(io::ErrorKind::WouldBlock.into());
                }
                let n = buf.len().min(self.budget);
                self.budget -= n;
                self.out.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let (ob, _pool) = outbox();
        ob.send(b"abcdefgh".to_vec());
        let mut w = Blocky {
            out: Vec::new(),
            budget: 3,
        };
        assert!(!ob.flush(&mut w).unwrap(), "short write leaves residue");
        assert!(ob.pending());
        w.budget = 100;
        assert!(ob.flush(&mut w).unwrap());
        assert_eq!(w.out, b"abcdefgh");
    }

    #[test]
    fn closed_outbox_recycles_frames() {
        let (ob, pool) = outbox();
        ob.send(b"queued".to_vec());
        ob.close();
        assert!(!ob.pending());
        assert!(!ob.send(b"late".to_vec()), "sends after close are no-ops");
        // Both buffers went back to the pool.
        let b1 = pool.take();
        let b2 = pool.take();
        assert!(b1.capacity() > 0 && b2.capacity() > 0);
        let (hits, _) = pool.counters();
        assert_eq!(hits, 2);
    }

    #[test]
    fn wake_hub_collects_dirty_tokens() {
        let (waker, mut rx) = Waker::pair().unwrap();
        let hub = WakeHub::new(waker);
        hub.notify(1);
        hub.notify(2);
        hub.notify(1);
        let mut sink = [0u8; 16];
        assert!(matches!(rx.read(&mut sink), Ok(n) if n > 0));
        drain_wakes(&mut rx);
        let mut toks = Vec::new();
        hub.drain(&mut toks);
        toks.sort_unstable();
        toks.dedup();
        assert_eq!(toks, vec![1, 2]);
        toks.clear();
        hub.drain(&mut toks);
        assert!(toks.is_empty());
    }
}
