//! Framing codecs: how protocol values become bytes on a socket.
//!
//! Two framings, negotiated per-session in `hello` (protocol v7):
//!
//! - **ndjson** — one JSON object per `\n`-terminated line. Default.
//!   Human-readable, `nc`-debuggable, and what every pre-v7 peer
//!   speaks.
//! - **binary** — `[u32 LE payload length][payload]` where the payload
//!   is a compact tagged binary encoding of the same JSON value tree
//!   (tag byte per node, LEB128 varint lengths, f64 as 8 LE bytes).
//!   No escaping, no float formatting/reparsing, and the decoder knows
//!   frame boundaries up front — meaningfully cheaper per message on
//!   hot serving paths.
//!
//! Everything here is a pure function over byte buffers: the blocking
//! per-thread path, the readiness event loop, the client, and the
//! router's backend connections all share this code. [`FrameDecoder`]
//! is an incremental state machine — bytes arrive in arbitrary splits
//! (partial reads) and frames are surfaced exactly once, complete.

use anyhow::{bail, Context, Result};
use std::io::Read;

use crate::util::json::{self, Json};

/// Hard cap on a single frame's payload; a peer announcing more than
/// this is corrupt or hostile and the connection is dropped.
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

/// How many bytes a decoder pulls from the socket per `fill_from`.
const READ_CHUNK: usize = 16 * 1024;

/// Wire framing for one session, fixed after `hello` negotiation.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum Framing {
    #[default]
    Ndjson,
    Binary,
}

impl Framing {
    pub fn parse(s: &str) -> Result<Framing> {
        match s {
            "ndjson" | "json" => Ok(Framing::Ndjson),
            "binary" | "bin" => Ok(Framing::Binary),
            other => bail!("unknown framing '{other}' (expected ndjson|binary)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Framing::Ndjson => "ndjson",
            Framing::Binary => "binary",
        }
    }
}

// ---------------------------------------------------------------- binary value codec

const TAG_NULL: u8 = 0;
const TAG_FALSE: u8 = 1;
const TAG_TRUE: u8 = 2;
const TAG_NUM: u8 = 3;
const TAG_STR: u8 = 4;
const TAG_ARR: u8 = 5;
const TAG_OBJ: u8 = 6;

/// Nesting depth cap for the binary decoder (the JSON parser has an
/// equivalent guard); protocol messages are at most 3 levels deep.
const MAX_DEPTH: u32 = 64;

fn put_varint(out: &mut Vec<u8>, mut n: u64) {
    loop {
        let b = (n & 0x7f) as u8;
        n >>= 7;
        if n == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Append the tagged binary encoding of `v` to `out`.
pub fn encode_value(v: &Json, out: &mut Vec<u8>) {
    match v {
        Json::Null => out.push(TAG_NULL),
        Json::Bool(false) => out.push(TAG_FALSE),
        Json::Bool(true) => out.push(TAG_TRUE),
        Json::Num(x) => {
            out.push(TAG_NUM);
            out.extend_from_slice(&x.to_le_bytes());
        }
        Json::Str(s) => {
            out.push(TAG_STR);
            put_varint(out, s.len() as u64);
            out.extend_from_slice(s.as_bytes());
        }
        Json::Arr(items) => {
            out.push(TAG_ARR);
            put_varint(out, items.len() as u64);
            for it in items {
                encode_value(it, out);
            }
        }
        Json::Obj(map) => {
            out.push(TAG_OBJ);
            put_varint(out, map.len() as u64);
            for (k, val) in map {
                put_varint(out, k.len() as u64);
                out.extend_from_slice(k.as_bytes());
                encode_value(val, out);
            }
        }
    }
}

struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn u8(&mut self) -> Result<u8> {
        let Some(&b) = self.b.get(self.i) else {
            bail!("truncated binary value at byte {}", self.i);
        };
        self.i += 1;
        Ok(b)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.i < n {
            bail!(
                "truncated binary value: need {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            );
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn varint(&mut self) -> Result<u64> {
        let mut n: u64 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift >= 63 && b > 1 {
                bail!("varint overflow in binary value");
            }
            n |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(n);
            }
            shift += 7;
        }
    }

    fn string(&mut self) -> Result<String> {
        let len = self.varint()? as usize;
        if len > MAX_FRAME {
            bail!("binary string of {len} bytes exceeds frame cap");
        }
        let bytes = self.take(len)?;
        Ok(std::str::from_utf8(bytes)
            .context("non-utf8 string in binary value")?
            .to_string())
    }
}

fn read_value(c: &mut Cur<'_>, depth: u32) -> Result<Json> {
    if depth > MAX_DEPTH {
        bail!("binary value nests deeper than {MAX_DEPTH}");
    }
    Ok(match c.u8()? {
        TAG_NULL => Json::Null,
        TAG_FALSE => Json::Bool(false),
        TAG_TRUE => Json::Bool(true),
        TAG_NUM => {
            let raw = c.take(8)?;
            let mut le = [0u8; 8];
            le.copy_from_slice(raw);
            Json::Num(f64::from_le_bytes(le))
        }
        TAG_STR => Json::Str(c.string()?),
        TAG_ARR => {
            let n = c.varint()? as usize;
            let mut items = Vec::new();
            for _ in 0..n {
                items.push(read_value(c, depth + 1)?);
            }
            Json::Arr(items)
        }
        TAG_OBJ => {
            let n = c.varint()? as usize;
            let mut map = std::collections::BTreeMap::new();
            for _ in 0..n {
                let k = c.string()?;
                let v = read_value(c, depth + 1)?;
                map.insert(k, v);
            }
            Json::Obj(map)
        }
        t => bail!("unknown binary value tag {t}"),
    })
}

/// Decode one complete binary payload; rejects trailing garbage.
pub fn decode_value(buf: &[u8]) -> Result<Json> {
    let mut c = Cur { b: buf, i: 0 };
    let v = read_value(&mut c, 0)?;
    if c.i != buf.len() {
        bail!("{} trailing bytes after binary value", buf.len() - c.i);
    }
    Ok(v)
}

// ---------------------------------------------------------------- framing

/// Append one complete frame carrying `v` in the given framing.
pub fn encode_frame(framing: Framing, v: &Json, out: &mut Vec<u8>) {
    match framing {
        Framing::Ndjson => {
            out.extend_from_slice(json::to_string(v).as_bytes());
            out.push(b'\n');
        }
        Framing::Binary => {
            let start = out.len();
            out.extend_from_slice(&[0u8; 4]);
            encode_value(v, out);
            let len = out.len() - start - 4;
            debug_assert!(len <= MAX_FRAME, "oversized outbound frame");
            out[start..start + 4].copy_from_slice(&(len as u32).to_le_bytes());
        }
    }
}

/// Incremental frame extractor. Feed it bytes in whatever chunks the
/// socket produces (`push` / `fill_from`); `next` yields each complete
/// message value exactly once, or `None` when more bytes are needed.
/// Switching framing mid-stream (after `hello`) is byte-exact: bytes
/// already buffered are reinterpreted under the new framing, so a peer
/// may pipeline its first binary frame right behind the ndjson hello.
pub struct FrameDecoder {
    framing: Framing,
    buf: Vec<u8>,
    start: usize,
}

impl FrameDecoder {
    pub fn new(framing: Framing) -> FrameDecoder {
        FrameDecoder::with_buffer(framing, Vec::new())
    }

    /// Build a decoder around a recycled buffer (see
    /// [`super::BufferPool`]); pair with [`FrameDecoder::into_buffer`].
    pub fn with_buffer(framing: Framing, mut buf: Vec<u8>) -> FrameDecoder {
        buf.clear();
        FrameDecoder {
            framing,
            buf,
            start: 0,
        }
    }

    pub fn framing(&self) -> Framing {
        self.framing
    }

    pub fn set_framing(&mut self, f: Framing) {
        self.framing = f;
    }

    /// Bytes buffered but not yet surfaced as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Reclaim the internal buffer for a pool.
    pub fn into_buffer(mut self) -> Vec<u8> {
        self.buf.clear();
        self.buf
    }

    fn compact(&mut self) {
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start > 4096 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    /// Append raw bytes (tests and in-memory paths).
    pub fn push(&mut self, bytes: &[u8]) {
        self.compact();
        self.buf.extend_from_slice(bytes);
    }

    /// Pull one read's worth of bytes from `r` into the buffer.
    /// Returns `Ok(0)` on EOF, propagates `WouldBlock`/`TimedOut`.
    pub fn fill_from(&mut self, r: &mut impl Read) -> std::io::Result<usize> {
        self.compact();
        let len = self.buf.len();
        self.buf.resize(len + READ_CHUNK, 0);
        match r.read(&mut self.buf[len..]) {
            Ok(n) => {
                self.buf.truncate(len + n);
                Ok(n)
            }
            Err(e) => {
                self.buf.truncate(len);
                Err(e)
            }
        }
    }

    /// Extract the next complete frame, if any. An error means the
    /// stream is corrupt and the connection should be closed.
    pub fn next(&mut self) -> Result<Option<Json>> {
        loop {
            match self.framing {
                Framing::Ndjson => {
                    let rel = match self.buf[self.start..].iter().position(|&b| b == b'\n') {
                        Some(i) => i,
                        None => {
                            if self.buffered() > MAX_FRAME {
                                bail!("ndjson line exceeds frame cap {MAX_FRAME}");
                            }
                            return Ok(None);
                        }
                    };
                    let line_start = self.start;
                    self.start += rel + 1;
                    let text = std::str::from_utf8(&self.buf[line_start..line_start + rel])
                        .context("non-utf8 ndjson frame")?
                        .trim();
                    if text.is_empty() {
                        continue; // tolerate blank keepalive lines
                    }
                    let v = json::parse(text)
                        .map_err(|e| anyhow::anyhow!("bad ndjson frame: {e}"))?;
                    return Ok(Some(v));
                }
                Framing::Binary => {
                    let avail = self.buf.len() - self.start;
                    if avail < 4 {
                        return Ok(None);
                    }
                    let p = self.start;
                    let len = u32::from_le_bytes([
                        self.buf[p],
                        self.buf[p + 1],
                        self.buf[p + 2],
                        self.buf[p + 3],
                    ]) as usize;
                    if len > MAX_FRAME {
                        bail!("binary frame of {len} bytes exceeds cap {MAX_FRAME}");
                    }
                    if avail < 4 + len {
                        return Ok(None);
                    }
                    let v = decode_value(&self.buf[p + 4..p + 4 + len])?;
                    self.start += 4 + len;
                    return Ok(Some(v));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn sample_value() -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("op".to_string(), Json::Str("submit".into()));
        obj.insert("id".to_string(), Json::Num(42.0));
        obj.insert("neg".to_string(), Json::Num(-1.5));
        obj.insert("ok".to_string(), Json::Bool(true));
        obj.insert("none".to_string(), Json::Null);
        obj.insert(
            "arr".to_string(),
            Json::Arr(vec![
                Json::Num(0.0),
                Json::Str("x\"esc\\ape\n".into()),
                Json::Bool(false),
                Json::Obj(BTreeMap::new()),
            ]),
        );
        Json::Obj(obj)
    }

    #[test]
    fn binary_value_roundtrips() {
        let v = sample_value();
        let mut bytes = Vec::new();
        encode_value(&v, &mut bytes);
        let back = decode_value(&bytes).unwrap();
        assert_eq!(json::to_string(&v), json::to_string(&back));
    }

    #[test]
    fn binary_value_rejects_garbage() {
        assert!(decode_value(&[]).is_err());
        assert!(decode_value(&[99]).is_err());
        // Truncated string payload.
        assert!(decode_value(&[TAG_STR, 10, b'a']).is_err());
        // Trailing bytes after a complete value.
        assert!(decode_value(&[TAG_NULL, TAG_NULL]).is_err());
    }

    #[test]
    fn frames_roundtrip_both_framings() {
        for framing in [Framing::Ndjson, Framing::Binary] {
            let v = sample_value();
            let mut wire = Vec::new();
            encode_frame(framing, &v, &mut wire);
            encode_frame(framing, &v, &mut wire);
            let mut dec = FrameDecoder::new(framing);
            dec.push(&wire);
            for _ in 0..2 {
                let got = dec.next().unwrap().expect("frame");
                assert_eq!(json::to_string(&v), json::to_string(&got));
            }
            assert!(dec.next().unwrap().is_none());
            assert_eq!(dec.buffered(), 0);
        }
    }

    #[test]
    fn decoder_resumes_across_partial_reads() {
        // Feed the wire image one byte at a time: frames must surface
        // exactly once each, only when complete.
        for framing in [Framing::Ndjson, Framing::Binary] {
            let v = sample_value();
            let mut wire = Vec::new();
            for _ in 0..3 {
                encode_frame(framing, &v, &mut wire);
            }
            let mut dec = FrameDecoder::new(framing);
            let mut got = 0;
            for b in &wire {
                dec.push(std::slice::from_ref(b));
                while let Some(frame) = dec.next().unwrap() {
                    assert_eq!(json::to_string(&v), json::to_string(&frame));
                    got += 1;
                }
            }
            assert_eq!(got, 3, "framing {:?}", framing);
        }
    }

    #[test]
    fn decoder_switches_framing_mid_stream() {
        // ndjson hello followed immediately by a pipelined binary
        // frame in the same byte stream — the v7 negotiation shape.
        let v = sample_value();
        let mut wire = Vec::new();
        encode_frame(Framing::Ndjson, &v, &mut wire);
        encode_frame(Framing::Binary, &v, &mut wire);
        let mut dec = FrameDecoder::new(Framing::Ndjson);
        dec.push(&wire);
        let first = dec.next().unwrap().expect("ndjson frame");
        assert_eq!(json::to_string(&v), json::to_string(&first));
        dec.set_framing(Framing::Binary);
        let second = dec.next().unwrap().expect("binary frame");
        assert_eq!(json::to_string(&v), json::to_string(&second));
        assert!(dec.next().unwrap().is_none());
    }

    #[test]
    fn decoder_skips_blank_ndjson_lines() {
        let mut dec = FrameDecoder::new(Framing::Ndjson);
        dec.push(b"\n  \n{\"a\":1}\n");
        let v = dec.next().unwrap().expect("frame");
        assert_eq!(json::to_string(&v), "{\"a\":1}");
    }

    #[test]
    fn decoder_rejects_oversized_binary_frame() {
        let mut dec = FrameDecoder::new(Framing::Binary);
        dec.push(&(u32::MAX).to_le_bytes());
        assert!(dec.next().is_err());
    }

    #[test]
    fn varint_boundaries_roundtrip() {
        for n in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut out = Vec::new();
            put_varint(&mut out, n);
            let mut c = Cur { b: &out, i: 0 };
            assert_eq!(c.varint().unwrap(), n);
            assert_eq!(c.i, out.len());
        }
    }
}
