//! Blocking client for the component service: one TCP connection, one
//! outstanding request at a time (the protocol supports pipelining via
//! ids; the load generator opens one connection per simulated client
//! instead, which is also how it measures per-request latency honestly).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Result};

use super::protocol::{
    self, CtxDesc, Request, Response, ResultResp, StatsResp, SubmitReq, PROTOCOL_VERSION,
};

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    pub session: u64,
}

impl Client {
    /// Connect and perform the hello handshake.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone()?;
        let mut c = Client {
            reader: BufReader::new(stream),
            writer,
            session: 0,
        };
        c.send(&Request::Hello {
            client: format!("compar-client-{}", std::process::id()),
        })?;
        match c.recv()? {
            Response::Hello { session, version } => {
                if version != PROTOCOL_VERSION {
                    bail!("server speaks protocol v{version}, client v{PROTOCOL_VERSION}");
                }
                c.session = session;
            }
            other => bail!("expected hello, got {other:?}"),
        }
        Ok(c)
    }

    fn send(&mut self, r: &Request) -> Result<()> {
        let mut line = protocol::encode_request(r);
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Response> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        protocol::decode_response(&line)
    }

    /// Execute one request; blocks until the (possibly batched) reply.
    pub fn submit(&mut self, req: SubmitReq) -> Result<ResultResp> {
        let id = req.id;
        self.send(&Request::Submit(req))?;
        match self.recv()? {
            Response::Result(r) => {
                if r.id != id {
                    bail!("response id {} for request {id}", r.id);
                }
                Ok(r)
            }
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<StatsResp> {
        self.send(&Request::Stats)?;
        match self.recv()? {
            Response::Stats(s) => Ok(s),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn contexts(&mut self) -> Result<Vec<CtxDesc>> {
        self.send(&Request::Contexts)?;
        match self.recv()? {
            Response::Contexts { contexts } => Ok(contexts),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Ask the server to drain and exit (acknowledged before the drain).
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.send(&Request::Shutdown)?;
        match self.recv()? {
            Response::Shutdown => Ok(()),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Close the session politely.
    pub fn quit(mut self) -> Result<()> {
        self.send(&Request::Quit)?;
        match self.recv()? {
            Response::Bye => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }
}
