//! Blocking client for the component service. One TCP connection; the
//! simple [`Client::submit`] keeps one request outstanding, while
//! [`Client::send_submit`] / [`Client::recv_response`] expose the wire
//! protocol's correlation ids so callers (the load generator's
//! `--pipeline N` mode) can keep several requests in flight and match
//! out-of-order completions by id.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Result};

use super::protocol::{
    self, AutoscaleResp, CtxDesc, Request, Response, ResultResp, ShardDesc, StatsResp,
    StreamClosedResp, StreamOpenReq, StreamOpenedResp, SubmitReq, PROTOCOL_VERSION,
};
use crate::util::json::Json;

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    pub session: u64,
    /// v5: the effective latency SLO the server reported in its hello
    /// (None when autoscaling is off or no SLO is configured).
    pub slo_ms: Option<f64>,
}

impl Client {
    /// Connect and perform the hello handshake.
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_with_policy(addr, None)
    }

    /// Connect, optionally asking the server to run every submit on this
    /// session under `policy` ("greedy" | "calibrating" | "epsilon[:E]"
    /// | "epsilon-decayed[:E]" | "forced:VARIANT").
    pub fn connect_with_policy(addr: &str, policy: Option<&str>) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Client::handshake(stream, policy, None)
    }

    /// v5: connect, declaring this session's latency target — the
    /// autoscaler treats the tightest declared target per context as
    /// that context's SLO.
    pub fn connect_with_slo(addr: &str, policy: Option<&str>, slo_ms: f64) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Client::handshake(stream, policy, Some(slo_ms))
    }

    /// Connect with connect/read/write deadlines — for health probes,
    /// gossip and other periodic admin traffic, where one hung peer must
    /// not block the caller forever (a timed-out probe simply counts as
    /// the peer being down).
    pub fn connect_with_deadline(addr: &str, timeout: std::time::Duration) -> Result<Client> {
        use std::net::ToSocketAddrs;
        let sa = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow!("cannot resolve '{addr}'"))?;
        let stream = TcpStream::connect_timeout(&sa, timeout)?;
        let _ = stream.set_read_timeout(Some(timeout));
        let _ = stream.set_write_timeout(Some(timeout));
        Client::handshake(stream, None, None)
    }

    fn handshake(stream: TcpStream, policy: Option<&str>, slo_ms: Option<f64>) -> Result<Client> {
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone()?;
        let mut c = Client {
            reader: BufReader::new(stream),
            writer,
            session: 0,
            slo_ms: None,
        };
        c.send(&Request::Hello {
            client: format!("compar-client-{}", std::process::id()),
            policy: policy.map(str::to_string),
            slo_ms,
        })?;
        match c.recv()? {
            Response::Hello {
                session,
                version,
                slo_ms,
            } => {
                if version != PROTOCOL_VERSION {
                    bail!("server speaks protocol v{version}, client v{PROTOCOL_VERSION}");
                }
                c.session = session;
                c.slo_ms = slo_ms;
            }
            Response::Error { error, .. } => bail!("server rejected hello: {error}"),
            other => bail!("expected hello, got {other:?}"),
        }
        Ok(c)
    }

    fn send(&mut self, r: &Request) -> Result<()> {
        let mut line = protocol::encode_request(r);
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Response> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        protocol::decode_response(&line)
    }

    /// Fire a submit without waiting for the reply (pipelining). Pair
    /// with [`Client::recv_response`] and match replies by request id.
    pub fn send_submit(&mut self, req: SubmitReq) -> Result<()> {
        self.send(&Request::Submit(req))
    }

    /// Receive the next response line (pipelining).
    pub fn recv_response(&mut self) -> Result<Response> {
        self.recv()
    }

    /// Execute one request; blocks until the (possibly batched) reply.
    pub fn submit(&mut self, req: SubmitReq) -> Result<ResultResp> {
        let id = req.id;
        self.send(&Request::Submit(req))?;
        match self.recv()? {
            Response::Result(r) => {
                if r.id != id {
                    bail!("response id {} for request {id}", r.id);
                }
                Ok(r)
            }
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v6: open a stream session; blocks for the `stream_opened` grant.
    pub fn stream_open(&mut self, req: StreamOpenReq) -> Result<StreamOpenedResp> {
        let id = req.id;
        self.send(&Request::StreamOpen(req))?;
        match self.recv()? {
            Response::StreamOpened(o) => {
                if o.stream != id {
                    bail!("stream_opened for stream {} (opened {id})", o.stream);
                }
                Ok(o)
            }
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v6: push one chunk without waiting (credit-window pipelining).
    /// Pair with [`Client::recv_response`] and track `stream_ack` /
    /// `stream_credit` events to respect the server's grant.
    pub fn send_stream_chunk(&mut self, stream: u64, seq: u64, seed: u64) -> Result<()> {
        self.send(&Request::StreamChunk { stream, seq, seed })
    }

    /// v6: ask the server to flush and close a stream, then read events
    /// until the `stream_closed` summary arrives (acks and credit
    /// signals for still-in-flight chunks are drained and discarded).
    pub fn stream_close(&mut self, stream: u64) -> Result<StreamClosedResp> {
        self.send(&Request::StreamClose { stream })?;
        loop {
            match self.recv()? {
                Response::StreamClosed(c) if c.stream == stream => return Ok(c),
                Response::StreamAck(_) | Response::StreamCredit(_) | Response::StreamClosed(_) => {
                    continue
                }
                Response::Error { error, .. } => return Err(anyhow!("server error: {error}")),
                other => bail!("unexpected response {other:?}"),
            }
        }
    }

    pub fn stats(&mut self) -> Result<StatsResp> {
        self.send(&Request::Stats)?;
        match self.recv()? {
            Response::Stats(s) => Ok(s),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn contexts(&mut self) -> Result<Vec<CtxDesc>> {
        self.send(&Request::Contexts)?;
        match self.recv()? {
            Response::Contexts { contexts } => Ok(contexts),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v5: the elastic-scaling control loop's live state (worker moves
    /// on a shard; shard spawn/retire counters on the router).
    pub fn autoscale_status(&mut self) -> Result<AutoscaleResp> {
        self.send(&Request::AutoscaleStatus)?;
        match self.recv()? {
            Response::Autoscale(a) => Ok(a),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v3 (shard): fetch the server's locally observed perf-model bucket
    /// summaries (the gossip payload).
    pub fn perf_pull(&mut self) -> Result<Json> {
        self.send(&Request::PerfPull)?;
        match self.recv()? {
            Response::PerfModels { models } => Ok(models),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v3 (shard): install `models` as the server's remote perf-model
    /// overlay; returns the number of buckets accepted.
    pub fn perf_push(&mut self, models: &Json) -> Result<u64> {
        self.send(&Request::PerfPush {
            models: models.clone(),
        })?;
        match self.recv()? {
            Response::PerfAck { merged } => Ok(merged),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v3 (router): the shard health/load table.
    pub fn shards(&mut self) -> Result<Vec<ShardDesc>> {
        self.send(&Request::Shards)?;
        match self.recv()? {
            Response::Shards { shards } => Ok(shards),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v3 (router): drain a shard (by address or `shardN`) out of the
    /// routing rotation.
    pub fn drain_shard(&mut self, shard: &str) -> Result<String> {
        self.send(&Request::DrainShard {
            shard: shard.to_string(),
        })?;
        match self.recv()? {
            Response::Drained { shard } => Ok(shard),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Ask the server to drain and exit (acknowledged before the drain).
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.send(&Request::Shutdown)?;
        match self.recv()? {
            Response::Shutdown => Ok(()),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Close the session politely.
    pub fn quit(mut self) -> Result<()> {
        self.send(&Request::Quit)?;
        match self.recv()? {
            Response::Bye => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }
}
