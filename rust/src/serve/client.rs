//! Blocking client for the component service. One TCP connection; the
//! simple [`Client::submit`] keeps one request outstanding, while
//! [`Client::send_submit`] / [`Client::recv_response`] expose the wire
//! protocol's correlation ids so callers (the load generator's
//! `--pipeline N` mode) can keep several requests in flight and match
//! out-of-order completions by id.
//!
//! v7: [`ClientConfig`] carries the session's wire framing (requested
//! in hello, confirmed by the server's echo) and the socket deadlines.
//! Every connect sets a *write* deadline — symmetric with the read
//! side, so a server that stops reading can never wedge a client (or a
//! router backend) inside a blocking send.

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::protocol::{
    self, AutoscaleResp, CtxDesc, DecisionsResp, GraphDoneResp, MetricsResp, Request, Response,
    ResultResp, ShardDesc, StatsResp, StreamClosedResp, StreamOpenReq, StreamOpenedResp,
    SubmitGraphReq, SubmitReq, TraceResp, PROTOCOL_VERSION,
};
use super::transport::codec::{encode_frame, FrameDecoder, Framing};
use crate::util::json::Json;

/// Default write deadline for ordinary clients: reads may legitimately
/// block for as long as a submit takes to execute, but a write only
/// blocks when the peer has stopped draining its socket.
pub const DEFAULT_WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Connection configuration for [`Client::connect_cfg`]; the named
/// constructors below are shorthands over it.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Session selection policy ("greedy" | "calibrating" |
    /// "epsilon[:E]" | "epsilon-decayed[:E]" | "forced:VARIANT").
    pub policy: Option<String>,
    /// v5: the session's declared latency target.
    pub slo_ms: Option<f64>,
    /// v7: wire framing to request in hello. The server echoes what it
    /// accepted; the session switches only on that confirmation.
    pub framing: Framing,
    /// Connect deadline (None = the OS default).
    pub connect_timeout: Option<Duration>,
    /// Read deadline; None = block for as long as a request takes
    /// (normal traffic). Admin/probe traffic sets one.
    pub read_timeout: Option<Duration>,
    /// Write deadline; always on by default (see
    /// [`DEFAULT_WRITE_TIMEOUT`]).
    pub write_timeout: Option<Duration>,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig {
            policy: None,
            slo_ms: None,
            framing: Framing::Ndjson,
            connect_timeout: None,
            read_timeout: None,
            write_timeout: Some(DEFAULT_WRITE_TIMEOUT),
        }
    }
}

pub struct Client {
    stream: TcpStream,
    writer: TcpStream,
    dec: FrameDecoder,
    /// Negotiated wire framing (requested framing, if the server
    /// confirmed it in its hello echo).
    framing: Framing,
    pub session: u64,
    /// v5: the effective latency SLO the server reported in its hello
    /// (None when autoscaling is off or no SLO is configured).
    pub slo_ms: Option<f64>,
}

impl Client {
    /// Connect and perform the hello handshake.
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_cfg(addr, &ClientConfig::default())
    }

    /// Connect, optionally asking the server to run every submit on this
    /// session under `policy` ("greedy" | "calibrating" | "epsilon[:E]"
    /// | "epsilon-decayed[:E]" | "forced:VARIANT").
    pub fn connect_with_policy(addr: &str, policy: Option<&str>) -> Result<Client> {
        Client::connect_cfg(
            addr,
            &ClientConfig {
                policy: policy.map(str::to_string),
                ..ClientConfig::default()
            },
        )
    }

    /// v5: connect, declaring this session's latency target — the
    /// autoscaler treats the tightest declared target per context as
    /// that context's SLO.
    pub fn connect_with_slo(addr: &str, policy: Option<&str>, slo_ms: f64) -> Result<Client> {
        Client::connect_cfg(
            addr,
            &ClientConfig {
                policy: policy.map(str::to_string),
                slo_ms: Some(slo_ms),
                ..ClientConfig::default()
            },
        )
    }

    /// Connect with connect/read/write deadlines — for health probes,
    /// gossip and other periodic admin traffic, where one hung peer must
    /// not block the caller forever (a timed-out probe simply counts as
    /// the peer being down).
    pub fn connect_with_deadline(addr: &str, timeout: Duration) -> Result<Client> {
        Client::connect_cfg(
            addr,
            &ClientConfig {
                connect_timeout: Some(timeout),
                read_timeout: Some(timeout),
                write_timeout: Some(timeout),
                ..ClientConfig::default()
            },
        )
    }

    /// Connect with the full configuration (framing, deadlines, policy).
    pub fn connect_cfg(addr: &str, cfg: &ClientConfig) -> Result<Client> {
        let stream = match cfg.connect_timeout {
            Some(t) => {
                use std::net::ToSocketAddrs;
                let sa = addr
                    .to_socket_addrs()?
                    .next()
                    .ok_or_else(|| anyhow!("cannot resolve '{addr}'"))?;
                TcpStream::connect_timeout(&sa, t)?
            }
            None => TcpStream::connect(addr)?,
        };
        let _ = stream.set_read_timeout(cfg.read_timeout);
        let _ = stream.set_write_timeout(cfg.write_timeout);
        Client::handshake(stream, cfg)
    }

    fn handshake(stream: TcpStream, cfg: &ClientConfig) -> Result<Client> {
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone()?;
        let mut c = Client {
            stream,
            writer,
            dec: FrameDecoder::new(Framing::Ndjson),
            // the hello exchange itself is always ndjson
            framing: Framing::Ndjson,
            session: 0,
            slo_ms: None,
        };
        c.send(&Request::Hello {
            client: format!("compar-client-{}", std::process::id()),
            policy: cfg.policy.clone(),
            slo_ms: cfg.slo_ms,
            framing: match cfg.framing {
                Framing::Ndjson => None,
                f => Some(f.name().to_string()),
            },
        })?;
        match c.recv()? {
            Response::Hello {
                session,
                version,
                slo_ms,
                framing,
            } => {
                if version != PROTOCOL_VERSION {
                    bail!("server speaks protocol v{version}, client v{PROTOCOL_VERSION}");
                }
                c.session = session;
                c.slo_ms = slo_ms;
                // switch only on the server's confirmation; a server
                // that stays silent keeps the session on ndjson
                if let Some(f) = framing.as_deref() {
                    let accepted = Framing::parse(f)?;
                    c.framing = accepted;
                    c.dec.set_framing(accepted);
                }
            }
            Response::Error { error, .. } => bail!("server rejected hello: {error}"),
            other => bail!("expected hello, got {other:?}"),
        }
        Ok(c)
    }

    /// The session's negotiated wire framing.
    pub fn framing(&self) -> Framing {
        self.framing
    }

    fn send(&mut self, r: &Request) -> Result<()> {
        let mut buf = Vec::with_capacity(128);
        encode_frame(self.framing, &protocol::request_value(r), &mut buf);
        self.writer.write_all(&buf)?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Response> {
        loop {
            if let Some(v) = self.dec.next()? {
                return protocol::response_from_value(&v);
            }
            if self.dec.fill_from(&mut self.stream)? == 0 {
                bail!("server closed the connection");
            }
        }
    }

    /// Fire a submit without waiting for the reply (pipelining). Pair
    /// with [`Client::recv_response`] and match replies by request id.
    pub fn send_submit(&mut self, req: SubmitReq) -> Result<()> {
        self.send(&Request::Submit(req))
    }

    /// Receive the next response line (pipelining).
    pub fn recv_response(&mut self) -> Result<Response> {
        self.recv()
    }

    /// Execute one request; blocks until the (possibly batched) reply.
    pub fn submit(&mut self, req: SubmitReq) -> Result<ResultResp> {
        let id = req.id;
        self.send(&Request::Submit(req))?;
        match self.recv()? {
            Response::Result(r) => {
                if r.id != id {
                    bail!("response id {} for request {id}", r.id);
                }
                Ok(r)
            }
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v8: submit a whole task DAG for joint variant planning; blocks
    /// until every node completed and the `graph_done` report (per-node
    /// variant, arch, modeled vs wall timing, elided edges) arrives.
    pub fn submit_graph(&mut self, req: SubmitGraphReq) -> Result<GraphDoneResp> {
        let id = req.id;
        self.send(&Request::SubmitGraph(req))?;
        match self.recv()? {
            Response::GraphDone(g) => {
                if g.id != id {
                    bail!("graph_done id {} for request {id}", g.id);
                }
                Ok(g)
            }
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v6: open a stream session; blocks for the `stream_opened` grant.
    pub fn stream_open(&mut self, req: StreamOpenReq) -> Result<StreamOpenedResp> {
        let id = req.id;
        self.send(&Request::StreamOpen(req))?;
        match self.recv()? {
            Response::StreamOpened(o) => {
                if o.stream != id {
                    bail!("stream_opened for stream {} (opened {id})", o.stream);
                }
                Ok(o)
            }
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v6: push one chunk without waiting (credit-window pipelining).
    /// Pair with [`Client::recv_response`] and track `stream_ack` /
    /// `stream_credit` events to respect the server's grant.
    pub fn send_stream_chunk(&mut self, stream: u64, seq: u64, seed: u64) -> Result<()> {
        self.send(&Request::StreamChunk { stream, seq, seed })
    }

    /// v6: ask the server to flush and close a stream, then read events
    /// until the `stream_closed` summary arrives (acks and credit
    /// signals for still-in-flight chunks are drained and discarded).
    pub fn stream_close(&mut self, stream: u64) -> Result<StreamClosedResp> {
        self.send(&Request::StreamClose { stream })?;
        loop {
            match self.recv()? {
                Response::StreamClosed(c) if c.stream == stream => return Ok(c),
                Response::StreamAck(_) | Response::StreamCredit(_) | Response::StreamClosed(_) => {
                    continue
                }
                Response::Error { error, .. } => return Err(anyhow!("server error: {error}")),
                other => bail!("unexpected response {other:?}"),
            }
        }
    }

    pub fn stats(&mut self) -> Result<StatsResp> {
        self.send(&Request::Stats)?;
        match self.recv()? {
            Response::Stats(s) => Ok(s),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v9: scrape the server's metrics registry. `format` is `None` /
    /// `"json"` for the JSON tree alone, `"prometheus"` to also get the
    /// text exposition in [`MetricsResp::text`]. Against a router the
    /// scrape aggregates every shard's registry under `shardN/` key
    /// prefixes.
    pub fn metrics(&mut self, format: Option<&str>) -> Result<MetricsResp> {
        self.send(&Request::Metrics {
            format: format.map(str::to_string),
        })?;
        match self.recv()? {
            Response::Metrics(m) => Ok(m),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v9: query the selection-decision audit ring — newest `limit`
    /// records (None = server default), optionally filtered by codelet
    /// name.
    pub fn decisions(&mut self, limit: Option<u64>, codelet: Option<&str>) -> Result<DecisionsResp> {
        self.send(&Request::Decisions {
            limit,
            codelet: codelet.map(str::to_string),
        })?;
        match self.recv()? {
            Response::Decisions(d) => Ok(d),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v9: flush the server's live trace ring as Chrome Trace Event
    /// Format JSON (load it in `chrome://tracing` or Perfetto).
    pub fn dump_trace(&mut self) -> Result<TraceResp> {
        self.send(&Request::DumpTrace)?;
        match self.recv()? {
            Response::DumpTrace(t) => Ok(t),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn contexts(&mut self) -> Result<Vec<CtxDesc>> {
        self.send(&Request::Contexts)?;
        match self.recv()? {
            Response::Contexts { contexts } => Ok(contexts),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v5: the elastic-scaling control loop's live state (worker moves
    /// on a shard; shard spawn/retire counters on the router).
    pub fn autoscale_status(&mut self) -> Result<AutoscaleResp> {
        self.send(&Request::AutoscaleStatus)?;
        match self.recv()? {
            Response::Autoscale(a) => Ok(a),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v3 (shard): fetch the server's locally observed perf-model bucket
    /// summaries (the gossip payload).
    pub fn perf_pull(&mut self) -> Result<Json> {
        Ok(self.perf_pull_full()?.0)
    }

    /// v8 (shard): like [`Client::perf_pull`], but also returns the
    /// shard's banded selection summary (None on pre-v8 peers or when
    /// the shard has observed nothing yet).
    pub fn perf_pull_full(&mut self) -> Result<(Json, Option<Json>)> {
        self.send(&Request::PerfPull)?;
        match self.recv()? {
            Response::PerfModels { models, bands } => Ok((models, bands)),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v3 (shard): install `models` as the server's remote perf-model
    /// overlay; returns the number of buckets accepted.
    pub fn perf_push(&mut self, models: &Json) -> Result<u64> {
        self.perf_push_full(models, None)
    }

    /// v8 (shard): push perf models and, optionally, a banded selection
    /// summary for the shard's contextual policies to merge.
    pub fn perf_push_full(&mut self, models: &Json, bands: Option<&Json>) -> Result<u64> {
        self.send(&Request::PerfPush {
            models: models.clone(),
            bands: bands.cloned(),
        })?;
        match self.recv()? {
            Response::PerfAck { merged } => Ok(merged),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v3 (router): the shard health/load table.
    pub fn shards(&mut self) -> Result<Vec<ShardDesc>> {
        self.send(&Request::Shards)?;
        match self.recv()? {
            Response::Shards { shards } => Ok(shards),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// v3 (router): drain a shard (by address or `shardN`) out of the
    /// routing rotation.
    pub fn drain_shard(&mut self, shard: &str) -> Result<String> {
        self.send(&Request::DrainShard {
            shard: shard.to_string(),
        })?;
        match self.recv()? {
            Response::Drained { shard } => Ok(shard),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Ask the server to drain and exit (acknowledged before the drain).
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.send(&Request::Shutdown)?;
        match self.recv()? {
            Response::Shutdown => Ok(()),
            Response::Error { error, .. } => Err(anyhow!("server error: {error}")),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Close the session politely.
    pub fn quit(mut self) -> Result<()> {
        self.send(&Request::Quit)?;
        match self.recv()? {
            Response::Bye => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }
}
