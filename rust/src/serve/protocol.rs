//! Wire protocol of the component service, hand-rolled over
//! [`crate::util::json`] — the offline image ships no serde. Every
//! message is self-describing (`"op"` on requests, `"type"` on
//! responses) and carries the client's request `id` back so batched /
//! out-of-order replies can be matched.
//!
//! Messages are JSON *values*; how a value becomes bytes is the
//! session's negotiated **framing** (v7, see
//! [`crate::serve::transport`]): newline-delimited JSON by default, or
//! a compact length-prefixed binary encoding of the same value tree.
//! This module therefore exposes both string-level helpers
//! ([`encode_request`]/[`decode_request`], ndjson) and value-level ones
//! ([`request_value`]/[`request_from_value`], framing-agnostic).
//!
//! ## v9 message set
//!
//! The same protocol is spoken at two levels: clients talk to either a
//! single `compar serve` shard or to a `compar route` router, and the
//! router talks to its shards. v9 (observability) adds the live
//! observability plane: `metrics` scrapes the server's metrics
//! registry (counters, gauges, latency histograms) as JSON or as
//! Prometheus-style text exposition (`"format":"prometheus"`), with
//! the router aggregating shard registries under per-shard key
//! prefixes; `decisions` queries the bounded selection-decision audit
//! ring (every `SelectionPolicy::select` records its query snapshot,
//! candidate estimates, chosen variant and reason tag); `dump_trace`
//! flushes the live trace ring as chrome://tracing Trace Event Format
//! JSON. Requests that mint a request-scoped trace id (`submit`,
//! `submit_graph`, `stream_open`) may carry `trace` on the wire so the
//! router can propagate ids to shards, and `result` echoes the id
//! back. `stats` gains monotonic totals (`tasks_completed`,
//! `bytes_transferred`, `batches_fused`, `decisions`) alongside its
//! point-in-time gauges. v8 (graph planning) adds whole-DAG
//! submission: `submit_graph` carries named nodes + data-dependency
//! edges, the server plans variant assignments jointly over the graph
//! before releasing any task ([`crate::plan`]), and `graph_done`
//! reports the per-node variant/arch/timing plan (including which
//! producer→consumer transfers were elided and whether the planner
//! degraded to per-task greedy). `stats` gains `plans` /
//! `planned_tasks` counters, and the perf-gossip pair may carry
//! contextual band summaries (`bands` on `perf_push` and on the
//! `perf_models` reply) so a plan computed on one shard prices
//! variants with cluster-wide interference evidence.
//! v7 (transport) adds the framing
//! handshake: a `hello` request may carry `"framing":"binary"` (or
//! `"ndjson"`, the default) and the `hello` response echoes the framing
//! the server accepted; the handshake itself is always exchanged in
//! ndjson, and every frame after it uses the negotiated framing. The
//! router forwards a session's framing to its backend shards; its admin
//! connections (health probes, shutdown fan-out) stay ndjson.
//! v6 (streaming) adds stream sessions:
//! `stream_open` declares a chunk pipeline (app, chunk size, stage
//! count, optional tumbling/sliding window, optional per-stream
//! `slo_ms`), `stream_chunk` pushes one chunk through it (every stage
//! selects its implementation variant per chunk), and `stream_close`
//! flushes and summarizes. Flow control is credit-based: the client may
//! keep at most `credit` chunks outstanding; each `stream_ack` carries
//! the current grant and the server pushes an unsolicited
//! `stream_credit` signal whenever SLO pressure moves it (backpressure
//! engages at half the SLO — before violation, never by dropping). v6
//! also surfaces the default context's effective `slo_ms` and the open
//! `streams` gauge in `stats`. v5 (elastic scaling) added the
//! `autoscale_status` request and a latency SLO in `hello`: a session
//! may declare `slo_ms`, which tightens the autoscaler's target for the
//! contexts it submits to for as long as the session lives; a shard's
//! hello response echoes the effective target (a router, which has no
//! context table of its own, omits it and forwards the declaration to
//! shards). v4 added the `contextual` selector and runtime-snapshot
//! fields to `stats`; v3 the cluster operations:
//!
//! | request `op`       | response `type` | level  | purpose                               |
//! |--------------------|-----------------|--------|---------------------------------------|
//! | `hello`            | `hello`         | both   | session handshake (+ policy, slo_ms,  |
//! |                    |                 |        | v7: `framing` negotiation)            |
//! | `submit`           | `result`        | both   | task-graph request (router fans out)  |
//! | `submit_graph`     | `graph_done`    | both   | whole-DAG request with jointly        |
//! |                    |                 |        | planned variants (v8); router         |
//! |                    |                 |        | forwards the graph whole to one shard |
//! | `stream_open`      | `stream_opened` | both   | open a stream session (v6); router    |
//! |                    |                 |        | pins the stream to one shard          |
//! | `stream_chunk`     | `stream_ack`    | both   | push one chunk through the pipeline;  |
//! |                    |                 |        | ack carries variants + credit grant   |
//! |                    | `stream_credit` | both   | unsolicited: credit/shed level moved  |
//! | `stream_close`     | `stream_closed` | both   | flush + summarize (p95, shed windows) |
//! | `stats`            | `stats`         | both   | counters (router aggregates shards);  |
//! |                    |                 |        | v6 adds `slo_ms` + `streams`; v9      |
//! |                    |                 |        | monotonic totals + `decisions`        |
//! | `metrics`          | `metrics`       | both   | v9: metrics-registry scrape, JSON or  |
//! |                    |                 |        | Prometheus text; router aggregates    |
//! |                    |                 |        | shards under per-shard labels         |
//! | `decisions`        | `decisions`     | both   | v9: selection-decision audit query    |
//! |                    |                 |        | (optional `limit` + `codelet` filter) |
//! | `dump_trace`       | `trace`         | both   | v9: flush the live trace ring as      |
//! |                    |                 |        | chrome://tracing JSON                 |
//! | `contexts`         | `contexts`      | both   | context table (router prefixes shard) |
//! | `autoscale_status` | `autoscale`     | both   | elastic-scaling state (v5): context   |
//! |                    |                 |        | bands in-process, shard churn on the  |
//! |                    |                 |        | router                                |
//! | `perf_pull`        | `perf_models`   | shard  | fetch locally observed perf-model     |
//! |                    |                 |        | bucket summaries (what gossip ships)  |
//! | `perf_push`        | `perf_ack`      | shard  | install the merged remote overlay     |
//! | `shards`           | `shards`        | router | shard health/load/drain table         |
//! | `drain_shard`      | `drained`       | router | take a shard out of rotation          |
//! | `shutdown`         | `shutdown`      | both   | drain and exit (router forwards)      |
//! | `quit`             | `bye`           | both   | close this session                    |
//!
//! Perf-model payloads are the serialized bucket summaries of
//! [`crate::taskrt::perfmodel::models_to_json`]: per (codelet:variant,
//! size), a fixed-size `{count, mean, m2, ewma, updated}` record —
//! counts/means/variances merge across shards by Welford combination,
//! decayed means by recency (fresher `updated` wins).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Json};

/// v9: observability — `metrics` scrapes the metrics registry (JSON or
/// Prometheus text), `decisions` queries the selection-decision audit
/// ring, `dump_trace` flushes the live trace ring as chrome://tracing
/// JSON; `submit`/`submit_graph`/`stream_open` may carry a `trace` id
/// (router→shard propagation) echoed on `result`, and `stats` gains
/// monotonic totals. (v8: graph planning — `submit_graph`/`graph_done`
/// whole-DAG requests
/// with jointly planned variant assignments, `plans`/`planned_tasks`
/// counters in `stats`, and optional contextual band summaries riding
/// the perf-gossip pair; v7 transport — the `hello` exchange
/// negotiates a per-session
/// framing (`"framing":"ndjson"|"binary"` on the request, echoed on
/// the response); the handshake is always ndjson and every later frame
/// uses the negotiated framing. v6 streaming —
/// `stream_open`/`stream_chunk`/`stream_close` stream sessions with
/// per-chunk variant selection, windowed operators, and credit-based
/// backpressure (`stream_credit`), plus `slo_ms`/`streams` in `stats`;
/// v5 elastic scaling — `autoscale_status` and a latency SLO in
/// `hello`; v4 the `contextual` session selector and runtime-snapshot
/// fields in `stats`; v3 cluster ops — `perf_pull`/`perf_push`
/// perf-model gossip on shards, `shards`/`drain_shard` rotation control
/// on the router; v2 per-session selection policy in `hello`, `policy`
/// on results, `selector` on context descriptors, `ctx_variants` in
/// stats.)
pub const PROTOCOL_VERSION: u64 = 9;

// --------------------------------------------------------------- requests

/// One task-graph execution request: `tasks` chained invocations of the
/// app's codelet over a single fresh problem instance (implicit data
/// dependencies serialize them), scheduled under context `ctx`.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitReq {
    /// Client-chosen correlation id, echoed in the response.
    pub id: u64,
    pub app: String,
    pub size: usize,
    /// Chain length (>= 1): task k reads/writes the same handles as
    /// task k-1, so the request is a real dependency graph.
    pub tasks: usize,
    /// Scheduling-context name (None = server default routing).
    pub ctx: Option<String>,
    pub seed: u64,
    /// Pin a variant (None = runtime selects — the paper's feature).
    pub variant: Option<String>,
    /// Verify the final output against the sequential reference.
    pub verify: bool,
    /// v9: request-scoped trace id (0 = unset — the receiving server
    /// mints one). A router mints the id and propagates it here so the
    /// shard's task spans correlate with the router hop.
    pub trace: u64,
}

/// v8: one node of a `submit_graph` DAG — a codelet invocation over a
/// fresh (or producer-shared) problem instance, depending by name on
/// earlier nodes in the same request.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphNodeReq {
    /// Node name, unique within the graph; keys the per-node report
    /// and the `deps` references of later nodes.
    pub name: String,
    pub app: String,
    pub size: usize,
    /// Names of earlier nodes this one consumes. A dependency on a
    /// same-app, same-size producer shares that producer's data
    /// handles (a real producer→consumer edge the planner can elide);
    /// other dependencies are ordering-only.
    pub deps: Vec<String>,
    /// Pin this node to one variant (None = the planner assigns).
    pub variant: Option<String>,
}

/// v8: a whole task DAG submitted as one unit — the server plans
/// variant assignments jointly over the graph before releasing any
/// task ([`crate::plan`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitGraphReq {
    /// Client-chosen correlation id, echoed in the response.
    pub id: u64,
    pub nodes: Vec<GraphNodeReq>,
    /// Scheduling-context name (None = server default routing).
    pub ctx: Option<String>,
    /// Planning mode: None or "planned" = joint lookahead (degrading
    /// to greedy under contention); "greedy" = force the per-task
    /// baseline over the identical release path (benchmarks).
    pub mode: Option<String>,
    /// v9: request-scoped trace id (0 = unset; see [`SubmitReq::trace`]).
    pub trace: u64,
}

/// v6: open a stream session — a long-lived chunk pipeline with
/// credit-based flow control (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamOpenReq {
    /// Client-chosen stream id, unique within the session; echoed on
    /// every stream message.
    pub id: u64,
    pub app: String,
    /// Elements per chunk.
    pub size: usize,
    /// Pipeline depth (>= 1): each chunk flows through `stages` chained
    /// codelet applications, each selecting its variant independently.
    pub stages: usize,
    /// Windowed operator: chunks per window (0 = none).
    pub window: usize,
    /// Chunks between window firings (0 = tumbling, i.e. `window`).
    pub slide: usize,
    /// Scheduling-context name (None = server default routing).
    pub ctx: Option<String>,
    /// Per-stream latency target driving backpressure; None falls back
    /// to the session-level `hello` declaration (if any).
    pub slo_ms: Option<f64>,
    /// v9: request-scoped trace id (0 = unset; see [`SubmitReq::trace`]).
    /// Every chunk task of the stream carries the stream's id.
    pub trace: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Session handshake. `policy` optionally picks a variant-selection
    /// policy for every submit on this session (e.g. "greedy",
    /// "epsilon:0.2", "forced:omp"); `None` = the scheduling context's
    /// policy decides. v5: `slo_ms` optionally declares this session's
    /// latency target — the autoscaler treats the tightest declared
    /// target per context as that context's SLO.
    Hello {
        client: String,
        policy: Option<String>,
        slo_ms: Option<f64>,
        /// v7: requested wire framing ("ndjson"|"binary"); absent/None
        /// means ndjson. The hello itself is always sent in ndjson.
        framing: Option<String>,
    },
    Submit(SubmitReq),
    /// v8: submit a whole task DAG with jointly planned variants.
    SubmitGraph(SubmitGraphReq),
    /// v6: open a stream session.
    StreamOpen(StreamOpenReq),
    /// v6: push one chunk (seeded input of the stream's declared size)
    /// through the stream's pipeline. `seq` is the client's monotonic
    /// chunk counter; the server acks chunks in sequence order.
    StreamChunk { stream: u64, seq: u64, seed: u64 },
    /// v6: flush outstanding chunks and close the stream.
    StreamClose { stream: u64 },
    Stats,
    /// v9: scrape the server's metrics registry. `format` is "json"
    /// (default) or "prometheus" (adds the text exposition rendering);
    /// the router aggregates shard registries under per-shard labels.
    Metrics { format: Option<String> },
    /// v9: query the selection-decision audit ring — newest `limit`
    /// records (server-capped), optionally filtered by codelet name.
    Decisions {
        limit: Option<u64>,
        codelet: Option<String>,
    },
    /// v9: flush the live trace ring as chrome://tracing JSON
    /// (request-scoped spans: router hop, admission, batch window,
    /// per-task execution).
    DumpTrace,
    Contexts,
    /// v5: the elastic-scaling control loop's live state (worker moves
    /// and per-context bands on a shard; shard spawn/retire counters on
    /// the router).
    AutoscaleStatus,
    /// v3 (shard): fetch this process's locally observed perf-model
    /// bucket summaries (the gossip payload).
    PerfPull,
    /// v3 (shard): install `models` as the remote perf-model overlay,
    /// replacing the previous one (idempotent gossip). v8: `bands`
    /// optionally carries contextual band summaries
    /// ([`crate::taskrt::SelectionPolicy::import_bands`]) so graph
    /// plans price variants with cluster-wide interference evidence.
    PerfPush { models: Json, bands: Option<Json> },
    /// v3 (router): list shard health/load/drain state.
    Shards,
    /// v3 (router): take a shard (by address, or `shardN`/index) out of
    /// the routing rotation; in-flight requests on it still complete.
    DrainShard { shard: String },
    /// Ask the server to drain and exit (graceful shutdown).
    Shutdown,
    /// Close this session only.
    Quit,
}

// -------------------------------------------------------------- responses

#[derive(Debug, Clone, PartialEq)]
pub struct ResultResp {
    pub id: u64,
    pub app: String,
    pub size: usize,
    /// Context name the request actually ran under.
    pub ctx: String,
    /// Selection policy that governed the request ("forced:V" for a
    /// pinned variant, the session policy, or the context's policy).
    pub policy: String,
    /// Per-task selected variant names, in chain order.
    pub variants: Vec<String>,
    /// Global worker ids that executed the tasks, in chain order.
    pub workers: Vec<usize>,
    /// How many requests rode in the same codelet batch.
    pub batch: usize,
    /// Summed modeled device seconds over the chain.
    pub modeled: f64,
    /// Summed wall-clock execution seconds over the chain.
    pub wall: f64,
    /// Relative L2 error vs the sequential reference (0.0 when
    /// verification was disabled).
    pub rel_err: f64,
    /// v9: the request-scoped trace id the server minted (or accepted
    /// from a router); keys `dump_trace` spans and `decisions` records.
    pub trace: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct CtxDesc {
    pub id: usize,
    pub name: String,
    pub policy: String,
    /// Variant-selection policy of this context ("greedy", ...).
    pub selector: String,
    pub workers: Vec<usize>,
    pub queued: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct StatsResp {
    pub uptime: f64,
    pub requests_ok: u64,
    pub requests_err: u64,
    /// Requests admitted but not yet completed.
    pub inflight: u64,
    pub tasks_executed: u64,
    /// v4 — runtime-snapshot features (the serve-side view of the
    /// selection layer's `RuntimeSnapshot`):
    /// tasks queued in the runtime's schedulers, not yet popped.
    pub queue_depth: u64,
    /// Workers currently executing a task.
    pub busy_workers: u64,
    /// Workers in the machine topology.
    pub total_workers: u64,
    /// Live client sessions (the co-tenant count).
    pub sessions: u64,
    /// Tasks executed per context name.
    pub ctx_tasks: BTreeMap<String, u64>,
    /// Per-context selection histogram: context name -> variant name ->
    /// tasks executed with that variant (the paper's §3.2 histogram,
    /// per tenant).
    pub ctx_variants: BTreeMap<String, BTreeMap<String, u64>>,
    /// v6 — the default context's *effective* latency SLO in
    /// milliseconds after session/stream declarations tightened it
    /// (0.0 = none configured or autoscaling off), so operators can see
    /// which tenants tightened context SLOs.
    pub slo_ms: f64,
    /// v6 — stream sessions currently open on this server.
    pub streams: u64,
    /// v8 — graph plans computed (`submit_graph` requests served).
    pub plans: u64,
    /// v8 — tasks released with planned variant priors.
    pub planned_tasks: u64,
    /// v9 — monotonic totals (never reset, unlike the point-in-time
    /// gauges above, which a scraper cannot difference): tasks the
    /// runtime completed successfully over the server's lifetime.
    pub tasks_completed: u64,
    /// v9 — bytes moved across memory nodes, monotonic.
    pub bytes_transferred: u64,
    /// v9 — same-codelet batches fused by the batcher (window size
    /// > 1), monotonic.
    pub batches_fused: u64,
    /// v9 — selection decisions recorded by the audit plane, monotonic.
    pub decisions: u64,
}

/// v8: per-node entry of the `graph_done` plan report.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphNodeReport {
    pub name: String,
    /// Variant that actually executed.
    pub variant: String,
    /// Architecture the plan assigned ("cpu"/"cuda").
    pub arch: String,
    /// The graph ran under a plan (mode "planned"). The reported
    /// `variant` may still differ from the plan's assignment when a
    /// worker exercised the prefer-strength escape hatch — compare
    /// `variant` against `est`/`arch` to observe prefer-vs-actual.
    pub planned: bool,
    /// The planner's modeled execution seconds behind the assignment.
    pub est: f64,
    /// Measured modeled device seconds of the node's task.
    pub modeled: f64,
    /// Measured wall-clock execution seconds of the node's task.
    pub wall: f64,
    /// At least one incoming data edge stayed on-arch (a transfer the
    /// per-task baseline would have paid).
    pub elided: bool,
}

/// v8: `graph_done` — the whole DAG completed; reports the plan and
/// per-node execution detail.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphDoneResp {
    pub id: u64,
    /// Context name the graph ran under.
    pub ctx: String,
    /// Mode actually used: "planned", or "greedy" when forced or when
    /// the planner degraded under contention — the degradation is
    /// observable here.
    pub mode: String,
    /// Modeled end-to-end seconds of the planned schedule.
    pub makespan: f64,
    /// Measured wall-clock seconds from release to last completion.
    pub wall: f64,
    /// Producer→consumer edges kept on one architecture.
    pub elided_transfers: u64,
    pub nodes: Vec<GraphNodeReport>,
}

/// v6: `stream_opened` — the stream is live; `credit` chunks may be
/// outstanding before the first ack.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamOpenedResp {
    pub stream: u64,
    /// Initial credit grant (max outstanding chunks).
    pub credit: u64,
    /// Normalized window size (0 = no windowed operator).
    pub window: usize,
    /// Normalized slide (equals `window` for tumbling windows).
    pub slide: usize,
    /// Effective SLO driving this stream's backpressure, if any.
    pub slo_ms: Option<f64>,
}

/// v6: `stream_ack` — one chunk completed its pipeline (and any window
/// firing that rode with it).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamAckResp {
    pub stream: u64,
    pub seq: u64,
    /// Context name the chunk ran under.
    pub ctx: String,
    /// Selected variant per task (pipeline stages in chain order, then
    /// the window task if one fired with this chunk).
    pub variants: Vec<String>,
    /// Global worker ids that executed the tasks, same order.
    pub workers: Vec<usize>,
    /// Summed modeled device seconds over the chunk's tasks.
    pub modeled: f64,
    /// Summed wall-clock execution seconds over the chunk's tasks.
    pub wall: f64,
    /// Submit-to-ack latency of this chunk (seconds).
    pub latency: f64,
    /// Current credit grant (the client's new outstanding cap).
    pub credit: u64,
    /// Current shed level (0 = full window granularity).
    pub shed: u64,
}

/// v6: `stream_credit` — unsolicited flow-control signal, pushed when
/// backlog pressure moves the credit grant or shed level.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamCreditResp {
    pub stream: u64,
    pub credit: u64,
    pub shed: u64,
    /// Modeled backlog (milliseconds of queued work) that priced this
    /// decision.
    pub queued_ms: f64,
}

/// v6: `stream_closed` — flush summary.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamClosedResp {
    pub stream: u64,
    /// Chunks acked over the stream's lifetime.
    pub chunks: u64,
    /// Chunks lost to submit/execution errors (0 in healthy runs —
    /// backpressure sheds granularity, never chunks).
    pub dropped: u64,
    /// Windows fired.
    pub windows: u64,
    /// Windows fired at reduced (shed) granularity.
    pub shed_windows: u64,
    /// Unsolicited `stream_credit` signals emitted.
    pub credit_signals: u64,
    /// p95 submit-to-ack chunk latency in milliseconds.
    pub p95_ms: f64,
}

/// v9: `metrics` — one registry scrape. `metrics` is the registry's
/// JSON tree (`{"counters":{},"gauges":{},"histograms":{}}`; a router
/// reply prefixes every key with `shardN/`, rendered as a
/// `shard="shardN"` label in the text exposition). `text` carries the
/// Prometheus-style rendering when `"format":"prometheus"` was asked.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsResp {
    pub metrics: Json,
    pub text: Option<String>,
}

/// v9: `decisions` — a slice of the selection-decision audit ring,
/// newest records last, plus the ring's lifetime counters.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionsResp {
    /// Decisions recorded since start (monotonic, includes evicted).
    pub total: u64,
    /// Records dropped because the ring was contended (never blocks
    /// the selection hot path).
    pub dropped: u64,
    /// Records evicted by capacity.
    pub evicted: u64,
    /// JSON array of decision records (see `crate::obs::DecisionRecord`).
    pub decisions: Json,
}

/// v9: `trace` — the live trace ring flushed as chrome://tracing
/// Trace Event Format JSON (`trace.traceEvents`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceResp {
    /// Span events included in the dump.
    pub events: u64,
    pub trace: Json,
}

/// One shard as the router sees it (`shards` response).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardDesc {
    pub addr: String,
    /// Last health probe succeeded.
    pub healthy: bool,
    /// Drained out of the routing rotation.
    pub draining: bool,
    /// Requests in flight on the shard at the last health poll.
    pub inflight: u64,
    /// Requests the shard had completed at the last health poll.
    pub requests_ok: u64,
}

/// One scheduling context in the `autoscale` response.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleCtxDesc {
    pub name: String,
    pub workers: u64,
    /// Worker count when the control loop started.
    pub home: u64,
    pub min: u64,
    /// 0 = unbounded.
    pub max: u64,
    pub queue_depth: u64,
    /// 0.0 = no SLO configured.
    pub slo_ms: f64,
}

/// The `autoscale_status` reply (v5) — spoken at both levels: a shard
/// reports worker moves between its scheduling contexts, the router
/// reports shard spawn/retire churn.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AutoscaleResp {
    pub enabled: bool,
    pub policy: String,
    /// Scale actions executed (in-process worker-migration batches).
    pub moves: u64,
    /// Workers migrated in total.
    pub moved_workers: u64,
    /// Human-readable description of the last executed action.
    pub last_action: Option<String>,
    pub contexts: Vec<AutoscaleCtxDesc>,
    /// Router level: shards currently in the table.
    pub shards: u64,
    /// Router level: shards spawned by the scaler.
    pub shards_spawned: u64,
    /// Router level: shards retired by the scaler.
    pub shards_retired: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Hello {
        session: u64,
        version: u64,
        /// v5: the effective latency SLO of the server's default
        /// context after applying the request's `slo_ms` (absent when
        /// autoscaling is off or no SLO is configured).
        slo_ms: Option<f64>,
        /// v7: the framing the server accepted for this session
        /// (absent = ndjson). Every frame after this hello uses it.
        framing: Option<String>,
    },
    Result(ResultResp),
    /// v8: whole-DAG request completed, with the per-node plan report.
    GraphDone(GraphDoneResp),
    /// v6: stream session opened.
    StreamOpened(StreamOpenedResp),
    /// v6: chunk completed.
    StreamAck(StreamAckResp),
    /// v6: unsolicited credit/shed update.
    StreamCredit(StreamCreditResp),
    /// v6: stream flushed and closed.
    StreamClosed(StreamClosedResp),
    Error { id: Option<u64>, error: String },
    Stats(StatsResp),
    /// v9: metrics-registry scrape.
    Metrics(MetricsResp),
    /// v9: selection-decision audit slice.
    Decisions(DecisionsResp),
    /// v9: live trace ring flushed as chrome://tracing JSON.
    DumpTrace(TraceResp),
    Contexts { contexts: Vec<CtxDesc> },
    /// v3: serialized perf-model bucket summaries (`perf_pull`). v8:
    /// `bands` optionally carries the shard's contextual band
    /// summaries ([`crate::taskrt::SelectionPolicy::export_bands`]).
    PerfModels { models: Json, bands: Option<Json> },
    /// v3: overlay installed; `merged` = (key, size) buckets accepted.
    PerfAck { merged: u64 },
    /// v3 (router): the shard table.
    Shards { shards: Vec<ShardDesc> },
    /// v3 (router): shard drained out of rotation.
    Drained { shard: String },
    /// v5: elastic-scaling state.
    Autoscale(AutoscaleResp),
    /// Shutdown acknowledged; the server drains after replying.
    Shutdown,
    /// Session closed.
    Bye,
}

// --------------------------------------------------------------- encoding

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn n(v: f64) -> Json {
    Json::Num(v)
}

fn nums(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| n(x as f64)).collect())
}

fn strs(v: &[String]) -> Json {
    Json::Arr(v.iter().map(|x| s(x)).collect())
}

/// Framing-agnostic encode: the request as a JSON value. The framing
/// codec ([`crate::serve::transport::codec`]) turns it into bytes.
pub fn request_value(r: &Request) -> Json {
    match r {
        Request::Hello {
            client,
            policy,
            slo_ms,
            framing,
        } => {
            let mut pairs = vec![("op", s("hello")), ("client", s(client))];
            if let Some(p) = policy {
                pairs.push(("policy", s(p)));
            }
            if let Some(ms) = slo_ms {
                pairs.push(("slo_ms", n(*ms)));
            }
            if let Some(f) = framing {
                pairs.push(("framing", s(f)));
            }
            obj(pairs)
        }
        Request::Submit(q) => {
            let mut pairs = vec![
                ("op", s("submit")),
                ("id", n(q.id as f64)),
                ("app", s(&q.app)),
                ("size", n(q.size as f64)),
                ("tasks", n(q.tasks as f64)),
                ("seed", n(q.seed as f64)),
                ("verify", Json::Bool(q.verify)),
            ];
            if let Some(c) = &q.ctx {
                pairs.push(("ctx", s(c)));
            }
            if let Some(v) = &q.variant {
                pairs.push(("variant", s(v)));
            }
            if q.trace != 0 {
                pairs.push(("trace", n(q.trace as f64)));
            }
            obj(pairs)
        }
        Request::SubmitGraph(q) => {
            let nodes = q
                .nodes
                .iter()
                .map(|nd| {
                    let mut pairs = vec![
                        ("name", s(&nd.name)),
                        ("app", s(&nd.app)),
                        ("size", n(nd.size as f64)),
                        ("deps", strs(&nd.deps)),
                    ];
                    if let Some(v) = &nd.variant {
                        pairs.push(("variant", s(v)));
                    }
                    obj(pairs)
                })
                .collect();
            let mut pairs = vec![
                ("op", s("submit_graph")),
                ("id", n(q.id as f64)),
                ("nodes", Json::Arr(nodes)),
            ];
            if let Some(c) = &q.ctx {
                pairs.push(("ctx", s(c)));
            }
            if let Some(m) = &q.mode {
                pairs.push(("mode", s(m)));
            }
            if q.trace != 0 {
                pairs.push(("trace", n(q.trace as f64)));
            }
            obj(pairs)
        }
        Request::StreamOpen(q) => {
            let mut pairs = vec![
                ("op", s("stream_open")),
                ("id", n(q.id as f64)),
                ("app", s(&q.app)),
                ("size", n(q.size as f64)),
                ("stages", n(q.stages as f64)),
                ("window", n(q.window as f64)),
                ("slide", n(q.slide as f64)),
            ];
            if let Some(c) = &q.ctx {
                pairs.push(("ctx", s(c)));
            }
            if let Some(ms) = q.slo_ms {
                pairs.push(("slo_ms", n(ms)));
            }
            if q.trace != 0 {
                pairs.push(("trace", n(q.trace as f64)));
            }
            obj(pairs)
        }
        Request::StreamChunk { stream, seq, seed } => obj(vec![
            ("op", s("stream_chunk")),
            ("stream", n(*stream as f64)),
            ("seq", n(*seq as f64)),
            ("seed", n(*seed as f64)),
        ]),
        Request::StreamClose { stream } => obj(vec![
            ("op", s("stream_close")),
            ("stream", n(*stream as f64)),
        ]),
        Request::Stats => obj(vec![("op", s("stats"))]),
        Request::Metrics { format } => {
            let mut pairs = vec![("op", s("metrics"))];
            if let Some(f) = format {
                pairs.push(("format", s(f)));
            }
            obj(pairs)
        }
        Request::Decisions { limit, codelet } => {
            let mut pairs = vec![("op", s("decisions"))];
            if let Some(l) = limit {
                pairs.push(("limit", n(*l as f64)));
            }
            if let Some(c) = codelet {
                pairs.push(("codelet", s(c)));
            }
            obj(pairs)
        }
        Request::DumpTrace => obj(vec![("op", s("dump_trace"))]),
        Request::Contexts => obj(vec![("op", s("contexts"))]),
        Request::AutoscaleStatus => obj(vec![("op", s("autoscale_status"))]),
        Request::PerfPull => obj(vec![("op", s("perf_pull"))]),
        Request::PerfPush { models, bands } => {
            let mut pairs = vec![("op", s("perf_push")), ("models", models.clone())];
            if let Some(b) = bands {
                pairs.push(("bands", b.clone()));
            }
            obj(pairs)
        }
        Request::Shards => obj(vec![("op", s("shards"))]),
        Request::DrainShard { shard } => {
            obj(vec![("op", s("drain_shard")), ("shard", s(shard))])
        }
        Request::Shutdown => obj(vec![("op", s("shutdown"))]),
        Request::Quit => obj(vec![("op", s("quit"))]),
    }
}

/// ndjson encode (one line, no trailing newline).
pub fn encode_request(r: &Request) -> String {
    json::to_string(&request_value(r))
}

/// Framing-agnostic encode: the response as a JSON value.
pub fn response_value(r: &Response) -> Json {
    match r {
        Response::Hello {
            session,
            version,
            slo_ms,
            framing,
        } => {
            let mut pairs = vec![
                ("ok", Json::Bool(true)),
                ("type", s("hello")),
                ("session", n(*session as f64)),
                ("version", n(*version as f64)),
            ];
            if let Some(ms) = slo_ms {
                pairs.push(("slo_ms", n(*ms)));
            }
            if let Some(f) = framing {
                pairs.push(("framing", s(f)));
            }
            obj(pairs)
        }
        Response::Result(q) => {
            let mut pairs = vec![
                ("ok", Json::Bool(true)),
                ("type", s("result")),
                ("id", n(q.id as f64)),
                ("app", s(&q.app)),
                ("size", n(q.size as f64)),
                ("ctx", s(&q.ctx)),
                ("policy", s(&q.policy)),
                ("variants", strs(&q.variants)),
                ("workers", nums(&q.workers)),
                ("batch", n(q.batch as f64)),
                ("modeled", n(q.modeled)),
                ("wall", n(q.wall)),
                ("rel_err", n(q.rel_err)),
            ];
            if q.trace != 0 {
                pairs.push(("trace", n(q.trace as f64)));
            }
            obj(pairs)
        }
        Response::GraphDone(q) => {
            let nodes = q
                .nodes
                .iter()
                .map(|nd| {
                    obj(vec![
                        ("name", s(&nd.name)),
                        ("variant", s(&nd.variant)),
                        ("arch", s(&nd.arch)),
                        ("planned", Json::Bool(nd.planned)),
                        ("est", n(nd.est)),
                        ("modeled", n(nd.modeled)),
                        ("wall", n(nd.wall)),
                        ("elided", Json::Bool(nd.elided)),
                    ])
                })
                .collect();
            obj(vec![
                ("ok", Json::Bool(true)),
                ("type", s("graph_done")),
                ("id", n(q.id as f64)),
                ("ctx", s(&q.ctx)),
                ("mode", s(&q.mode)),
                ("makespan", n(q.makespan)),
                ("wall", n(q.wall)),
                ("elided_transfers", n(q.elided_transfers as f64)),
                ("nodes", Json::Arr(nodes)),
            ])
        }
        Response::StreamOpened(q) => {
            let mut pairs = vec![
                ("ok", Json::Bool(true)),
                ("type", s("stream_opened")),
                ("stream", n(q.stream as f64)),
                ("credit", n(q.credit as f64)),
                ("window", n(q.window as f64)),
                ("slide", n(q.slide as f64)),
            ];
            if let Some(ms) = q.slo_ms {
                pairs.push(("slo_ms", n(ms)));
            }
            obj(pairs)
        }
        Response::StreamAck(q) => obj(vec![
            ("ok", Json::Bool(true)),
            ("type", s("stream_ack")),
            ("stream", n(q.stream as f64)),
            ("seq", n(q.seq as f64)),
            ("ctx", s(&q.ctx)),
            ("variants", strs(&q.variants)),
            ("workers", nums(&q.workers)),
            ("modeled", n(q.modeled)),
            ("wall", n(q.wall)),
            ("latency", n(q.latency)),
            ("credit", n(q.credit as f64)),
            ("shed", n(q.shed as f64)),
        ]),
        Response::StreamCredit(q) => obj(vec![
            ("ok", Json::Bool(true)),
            ("type", s("stream_credit")),
            ("stream", n(q.stream as f64)),
            ("credit", n(q.credit as f64)),
            ("shed", n(q.shed as f64)),
            ("queued_ms", n(q.queued_ms)),
        ]),
        Response::StreamClosed(q) => obj(vec![
            ("ok", Json::Bool(true)),
            ("type", s("stream_closed")),
            ("stream", n(q.stream as f64)),
            ("chunks", n(q.chunks as f64)),
            ("dropped", n(q.dropped as f64)),
            ("windows", n(q.windows as f64)),
            ("shed_windows", n(q.shed_windows as f64)),
            ("credit_signals", n(q.credit_signals as f64)),
            ("p95_ms", n(q.p95_ms)),
        ]),
        Response::Error { id, error } => {
            let mut pairs = vec![
                ("ok", Json::Bool(false)),
                ("type", s("error")),
                ("error", s(error)),
            ];
            if let Some(id) = id {
                pairs.push(("id", n(*id as f64)));
            }
            obj(pairs)
        }
        Response::Stats(q) => {
            let mut ctx_tasks = BTreeMap::new();
            for (k, v) in &q.ctx_tasks {
                ctx_tasks.insert(k.clone(), n(*v as f64));
            }
            let mut ctx_variants = BTreeMap::new();
            for (ctx, hist) in &q.ctx_variants {
                let mut h = BTreeMap::new();
                for (variant, count) in hist {
                    h.insert(variant.clone(), n(*count as f64));
                }
                ctx_variants.insert(ctx.clone(), Json::Obj(h));
            }
            obj(vec![
                ("ok", Json::Bool(true)),
                ("type", s("stats")),
                ("uptime", n(q.uptime)),
                ("requests_ok", n(q.requests_ok as f64)),
                ("requests_err", n(q.requests_err as f64)),
                ("inflight", n(q.inflight as f64)),
                ("tasks_executed", n(q.tasks_executed as f64)),
                ("queue_depth", n(q.queue_depth as f64)),
                ("busy_workers", n(q.busy_workers as f64)),
                ("total_workers", n(q.total_workers as f64)),
                ("sessions", n(q.sessions as f64)),
                ("ctx_tasks", Json::Obj(ctx_tasks)),
                ("ctx_variants", Json::Obj(ctx_variants)),
                ("slo_ms", n(q.slo_ms)),
                ("streams", n(q.streams as f64)),
                ("plans", n(q.plans as f64)),
                ("planned_tasks", n(q.planned_tasks as f64)),
                ("tasks_completed", n(q.tasks_completed as f64)),
                ("bytes_transferred", n(q.bytes_transferred as f64)),
                ("batches_fused", n(q.batches_fused as f64)),
                ("decisions", n(q.decisions as f64)),
            ])
        }
        Response::Metrics(q) => {
            let mut pairs = vec![
                ("ok", Json::Bool(true)),
                ("type", s("metrics")),
                ("metrics", q.metrics.clone()),
            ];
            if let Some(t) = &q.text {
                pairs.push(("text", s(t)));
            }
            obj(pairs)
        }
        Response::Decisions(q) => obj(vec![
            ("ok", Json::Bool(true)),
            ("type", s("decisions")),
            ("total", n(q.total as f64)),
            ("dropped", n(q.dropped as f64)),
            ("evicted", n(q.evicted as f64)),
            ("decisions", q.decisions.clone()),
        ]),
        Response::DumpTrace(q) => obj(vec![
            ("ok", Json::Bool(true)),
            ("type", s("trace")),
            ("events", n(q.events as f64)),
            ("trace", q.trace.clone()),
        ]),
        Response::Contexts { contexts } => {
            let arr = contexts
                .iter()
                .map(|c| {
                    obj(vec![
                        ("id", n(c.id as f64)),
                        ("name", s(&c.name)),
                        ("policy", s(&c.policy)),
                        ("selector", s(&c.selector)),
                        ("workers", nums(&c.workers)),
                        ("queued", n(c.queued as f64)),
                    ])
                })
                .collect();
            obj(vec![
                ("ok", Json::Bool(true)),
                ("type", s("contexts")),
                ("contexts", Json::Arr(arr)),
            ])
        }
        Response::PerfModels { models, bands } => {
            let mut pairs = vec![
                ("ok", Json::Bool(true)),
                ("type", s("perf_models")),
                ("models", models.clone()),
            ];
            if let Some(b) = bands {
                pairs.push(("bands", b.clone()));
            }
            obj(pairs)
        }
        Response::PerfAck { merged } => obj(vec![
            ("ok", Json::Bool(true)),
            ("type", s("perf_ack")),
            ("merged", n(*merged as f64)),
        ]),
        Response::Shards { shards } => {
            let arr = shards
                .iter()
                .map(|d| {
                    obj(vec![
                        ("addr", s(&d.addr)),
                        ("healthy", Json::Bool(d.healthy)),
                        ("draining", Json::Bool(d.draining)),
                        ("inflight", n(d.inflight as f64)),
                        ("requests_ok", n(d.requests_ok as f64)),
                    ])
                })
                .collect();
            obj(vec![
                ("ok", Json::Bool(true)),
                ("type", s("shards")),
                ("shards", Json::Arr(arr)),
            ])
        }
        Response::Drained { shard } => obj(vec![
            ("ok", Json::Bool(true)),
            ("type", s("drained")),
            ("shard", s(shard)),
        ]),
        Response::Autoscale(q) => {
            let ctxs = q
                .contexts
                .iter()
                .map(|c| {
                    obj(vec![
                        ("name", s(&c.name)),
                        ("workers", n(c.workers as f64)),
                        ("home", n(c.home as f64)),
                        ("min", n(c.min as f64)),
                        ("max", n(c.max as f64)),
                        ("queue_depth", n(c.queue_depth as f64)),
                        ("slo_ms", n(c.slo_ms)),
                    ])
                })
                .collect();
            let mut pairs = vec![
                ("ok", Json::Bool(true)),
                ("type", s("autoscale")),
                ("enabled", Json::Bool(q.enabled)),
                ("policy", s(&q.policy)),
                ("moves", n(q.moves as f64)),
                ("moved_workers", n(q.moved_workers as f64)),
                ("contexts", Json::Arr(ctxs)),
                ("shards", n(q.shards as f64)),
                ("shards_spawned", n(q.shards_spawned as f64)),
                ("shards_retired", n(q.shards_retired as f64)),
            ];
            if let Some(a) = &q.last_action {
                pairs.push(("last_action", s(a)));
            }
            obj(pairs)
        }
        Response::Shutdown => obj(vec![("ok", Json::Bool(true)), ("type", s("shutdown"))]),
        Response::Bye => obj(vec![("ok", Json::Bool(true)), ("type", s("bye"))]),
    }
}

/// ndjson encode (one line, no trailing newline).
pub fn encode_response(r: &Response) -> String {
    json::to_string(&response_value(r))
}

// --------------------------------------------------------------- decoding

fn get_str(j: &Json, k: &str) -> Result<String> {
    j.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing/invalid string field '{k}'"))
}

fn get_u64(j: &Json, k: &str) -> Result<u64> {
    j.get(k)
        .and_then(Json::as_f64)
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as u64)
        .ok_or_else(|| anyhow!("missing/invalid integer field '{k}'"))
}

fn get_f64(j: &Json, k: &str) -> Result<f64> {
    j.get(k)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing/invalid number field '{k}'"))
}

fn get_usize_arr(j: &Json, k: &str) -> Result<Vec<usize>> {
    j.get(k)
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .ok_or_else(|| anyhow!("missing/invalid array field '{k}'"))
}

fn get_str_arr(j: &Json, k: &str) -> Result<Vec<String>> {
    j.get(k)
        .and_then(Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(Json::as_str)
                .map(str::to_string)
                .collect()
        })
        .ok_or_else(|| anyhow!("missing/invalid array field '{k}'"))
}

/// Framing-agnostic decode: a request from its JSON value.
pub fn request_from_value(j: &Json) -> Result<Request> {
    let op = get_str(j, "op")?;
    Ok(match op.as_str() {
        "hello" => Request::Hello {
            client: get_str(j, "client").unwrap_or_default(),
            policy: get_str(j, "policy").ok(),
            slo_ms: get_f64(j, "slo_ms").ok(),
            framing: get_str(j, "framing").ok(),
        },
        "submit" => {
            let tasks = get_u64(&j, "tasks").unwrap_or(1).max(1) as usize;
            Request::Submit(SubmitReq {
                id: get_u64(&j, "id")?,
                app: get_str(&j, "app")?,
                size: get_u64(&j, "size")? as usize,
                tasks,
                ctx: get_str(&j, "ctx").ok(),
                seed: get_u64(&j, "seed").unwrap_or(0),
                variant: get_str(&j, "variant").ok(),
                verify: match j.get("verify") {
                    Some(Json::Bool(b)) => *b,
                    None => true,
                    _ => bail!("invalid 'verify' field"),
                },
                // v9 field: tolerant decode (0 = unset on older peers)
                trace: get_u64(&j, "trace").unwrap_or(0),
            })
        }
        "submit_graph" => {
            let arr = j
                .get("nodes")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing 'nodes'"))?;
            let mut nodes = Vec::new();
            for nd in arr {
                nodes.push(GraphNodeReq {
                    name: get_str(nd, "name")?,
                    app: get_str(nd, "app")?,
                    size: get_u64(nd, "size")? as usize,
                    deps: get_str_arr(nd, "deps").unwrap_or_default(),
                    variant: get_str(nd, "variant").ok(),
                });
            }
            if nodes.is_empty() {
                bail!("'submit_graph' needs at least one node");
            }
            Request::SubmitGraph(SubmitGraphReq {
                id: get_u64(j, "id")?,
                nodes,
                ctx: get_str(j, "ctx").ok(),
                mode: get_str(j, "mode").ok(),
                // v9 field: tolerant decode (0 = unset on older peers)
                trace: get_u64(j, "trace").unwrap_or(0),
            })
        }
        "stream_open" => Request::StreamOpen(StreamOpenReq {
            id: get_u64(&j, "id")?,
            app: get_str(&j, "app")?,
            size: get_u64(&j, "size")? as usize,
            stages: get_u64(&j, "stages").unwrap_or(1).max(1) as usize,
            window: get_u64(&j, "window").unwrap_or(0) as usize,
            slide: get_u64(&j, "slide").unwrap_or(0) as usize,
            ctx: get_str(&j, "ctx").ok(),
            slo_ms: get_f64(&j, "slo_ms").ok(),
            // v9 field: tolerant decode (0 = unset on older peers)
            trace: get_u64(&j, "trace").unwrap_or(0),
        }),
        "stream_chunk" => Request::StreamChunk {
            stream: get_u64(&j, "stream")?,
            seq: get_u64(&j, "seq")?,
            seed: get_u64(&j, "seed").unwrap_or(0),
        },
        "stream_close" => Request::StreamClose {
            stream: get_u64(&j, "stream")?,
        },
        "stats" => Request::Stats,
        "metrics" => Request::Metrics {
            format: get_str(&j, "format").ok(),
        },
        "decisions" => Request::Decisions {
            limit: get_u64(&j, "limit").ok(),
            codelet: get_str(&j, "codelet").ok(),
        },
        "dump_trace" => Request::DumpTrace,
        "contexts" => Request::Contexts,
        "autoscale_status" => Request::AutoscaleStatus,
        "perf_pull" => Request::PerfPull,
        "perf_push" => Request::PerfPush {
            models: j
                .get("models")
                .cloned()
                .unwrap_or(Json::Obj(BTreeMap::new())),
            bands: j.get("bands").cloned(),
        },
        "shards" => Request::Shards,
        "drain_shard" => Request::DrainShard {
            shard: get_str(&j, "shard")?,
        },
        "shutdown" => Request::Shutdown,
        "quit" => Request::Quit,
        other => bail!("unknown op '{other}'"),
    })
}

/// ndjson decode (one line).
pub fn decode_request(line: &str) -> Result<Request> {
    let j = json::parse(line.trim()).map_err(|e| anyhow!("bad request json: {e}"))?;
    request_from_value(&j)
}

/// Framing-agnostic decode: a response from its JSON value.
pub fn response_from_value(j: &Json) -> Result<Response> {
    let ty = get_str(j, "type")?;
    Ok(match ty.as_str() {
        "hello" => Response::Hello {
            session: get_u64(&j, "session")?,
            version: get_u64(&j, "version")?,
            slo_ms: get_f64(&j, "slo_ms").ok(),
            framing: get_str(j, "framing").ok(),
        },
        "result" => Response::Result(ResultResp {
            id: get_u64(&j, "id")?,
            app: get_str(&j, "app")?,
            size: get_u64(&j, "size")? as usize,
            ctx: get_str(&j, "ctx")?,
            policy: get_str(&j, "policy")?,
            variants: get_str_arr(&j, "variants")?,
            workers: get_usize_arr(&j, "workers")?,
            batch: get_u64(&j, "batch")? as usize,
            modeled: get_f64(&j, "modeled")?,
            wall: get_f64(&j, "wall")?,
            rel_err: get_f64(&j, "rel_err")?,
            // v9 field: tolerant decode (0 = untraced on older peers)
            trace: get_u64(&j, "trace").unwrap_or(0),
        }),
        "graph_done" => {
            let arr = j
                .get("nodes")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing 'nodes'"))?;
            let mut nodes = Vec::new();
            for nd in arr {
                nodes.push(GraphNodeReport {
                    name: get_str(nd, "name")?,
                    variant: get_str(nd, "variant")?,
                    arch: get_str(nd, "arch").unwrap_or_default(),
                    planned: matches!(nd.get("planned"), Some(Json::Bool(true))),
                    est: get_f64(nd, "est").unwrap_or(0.0),
                    modeled: get_f64(nd, "modeled").unwrap_or(0.0),
                    wall: get_f64(nd, "wall").unwrap_or(0.0),
                    elided: matches!(nd.get("elided"), Some(Json::Bool(true))),
                });
            }
            Response::GraphDone(GraphDoneResp {
                id: get_u64(j, "id")?,
                ctx: get_str(j, "ctx").unwrap_or_default(),
                mode: get_str(j, "mode")?,
                makespan: get_f64(j, "makespan").unwrap_or(0.0),
                wall: get_f64(j, "wall").unwrap_or(0.0),
                elided_transfers: get_u64(j, "elided_transfers").unwrap_or(0),
                nodes,
            })
        }
        "stream_opened" => Response::StreamOpened(StreamOpenedResp {
            stream: get_u64(&j, "stream")?,
            credit: get_u64(&j, "credit")?,
            window: get_u64(&j, "window").unwrap_or(0) as usize,
            slide: get_u64(&j, "slide").unwrap_or(0) as usize,
            slo_ms: get_f64(&j, "slo_ms").ok(),
        }),
        "stream_ack" => Response::StreamAck(StreamAckResp {
            stream: get_u64(&j, "stream")?,
            seq: get_u64(&j, "seq")?,
            ctx: get_str(&j, "ctx").unwrap_or_default(),
            variants: get_str_arr(&j, "variants").unwrap_or_default(),
            workers: get_usize_arr(&j, "workers").unwrap_or_default(),
            modeled: get_f64(&j, "modeled").unwrap_or(0.0),
            wall: get_f64(&j, "wall").unwrap_or(0.0),
            latency: get_f64(&j, "latency").unwrap_or(0.0),
            credit: get_u64(&j, "credit")?,
            shed: get_u64(&j, "shed").unwrap_or(0),
        }),
        "stream_credit" => Response::StreamCredit(StreamCreditResp {
            stream: get_u64(&j, "stream")?,
            credit: get_u64(&j, "credit")?,
            shed: get_u64(&j, "shed").unwrap_or(0),
            queued_ms: get_f64(&j, "queued_ms").unwrap_or(0.0),
        }),
        "stream_closed" => Response::StreamClosed(StreamClosedResp {
            stream: get_u64(&j, "stream")?,
            chunks: get_u64(&j, "chunks").unwrap_or(0),
            dropped: get_u64(&j, "dropped").unwrap_or(0),
            windows: get_u64(&j, "windows").unwrap_or(0),
            shed_windows: get_u64(&j, "shed_windows").unwrap_or(0),
            credit_signals: get_u64(&j, "credit_signals").unwrap_or(0),
            p95_ms: get_f64(&j, "p95_ms").unwrap_or(0.0),
        }),
        "error" => Response::Error {
            id: get_u64(&j, "id").ok(),
            error: get_str(&j, "error")?,
        },
        "stats" => {
            let mut ctx_tasks = BTreeMap::new();
            if let Some(o) = j.get("ctx_tasks").and_then(Json::as_obj) {
                for (k, v) in o {
                    if let Some(x) = v.as_f64() {
                        ctx_tasks.insert(k.clone(), x as u64);
                    }
                }
            }
            let mut ctx_variants = BTreeMap::new();
            if let Some(o) = j.get("ctx_variants").and_then(Json::as_obj) {
                for (ctx, hist) in o {
                    let mut h = BTreeMap::new();
                    if let Some(ho) = hist.as_obj() {
                        for (variant, count) in ho {
                            if let Some(x) = count.as_f64() {
                                h.insert(variant.clone(), x as u64);
                            }
                        }
                    }
                    ctx_variants.insert(ctx.clone(), h);
                }
            }
            Response::Stats(StatsResp {
                uptime: get_f64(&j, "uptime")?,
                requests_ok: get_u64(&j, "requests_ok")?,
                requests_err: get_u64(&j, "requests_err")?,
                inflight: get_u64(&j, "inflight")?,
                tasks_executed: get_u64(&j, "tasks_executed")?,
                // v4 snapshot fields: tolerant decode (0 when absent)
                queue_depth: get_u64(&j, "queue_depth").unwrap_or(0),
                busy_workers: get_u64(&j, "busy_workers").unwrap_or(0),
                total_workers: get_u64(&j, "total_workers").unwrap_or(0),
                sessions: get_u64(&j, "sessions").unwrap_or(0),
                ctx_tasks,
                ctx_variants,
                // v6 fields: tolerant decode (pre-v6 peers omit them)
                slo_ms: get_f64(&j, "slo_ms").unwrap_or(0.0),
                streams: get_u64(&j, "streams").unwrap_or(0),
                // v8 fields: tolerant decode (pre-v8 peers omit them)
                plans: get_u64(&j, "plans").unwrap_or(0),
                planned_tasks: get_u64(&j, "planned_tasks").unwrap_or(0),
                // v9 fields: tolerant decode (pre-v9 peers omit them)
                tasks_completed: get_u64(&j, "tasks_completed").unwrap_or(0),
                bytes_transferred: get_u64(&j, "bytes_transferred").unwrap_or(0),
                batches_fused: get_u64(&j, "batches_fused").unwrap_or(0),
                decisions: get_u64(&j, "decisions").unwrap_or(0),
            })
        }
        "metrics" => Response::Metrics(MetricsResp {
            metrics: j
                .get("metrics")
                .cloned()
                .unwrap_or(Json::Obj(BTreeMap::new())),
            text: get_str(&j, "text").ok(),
        }),
        "decisions" => Response::Decisions(DecisionsResp {
            total: get_u64(&j, "total").unwrap_or(0),
            dropped: get_u64(&j, "dropped").unwrap_or(0),
            evicted: get_u64(&j, "evicted").unwrap_or(0),
            decisions: j
                .get("decisions")
                .cloned()
                .unwrap_or(Json::Arr(Vec::new())),
        }),
        "trace" => Response::DumpTrace(TraceResp {
            events: get_u64(&j, "events").unwrap_or(0),
            trace: j
                .get("trace")
                .cloned()
                .unwrap_or(Json::Obj(BTreeMap::new())),
        }),
        "contexts" => {
            let arr = j
                .get("contexts")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing 'contexts'"))?;
            let mut contexts = Vec::new();
            for c in arr {
                contexts.push(CtxDesc {
                    id: get_u64(c, "id")? as usize,
                    name: get_str(c, "name")?,
                    policy: get_str(c, "policy")?,
                    selector: get_str(c, "selector")?,
                    workers: get_usize_arr(c, "workers")?,
                    queued: get_u64(c, "queued")? as usize,
                });
            }
            Response::Contexts { contexts }
        }
        "perf_models" => Response::PerfModels {
            models: j
                .get("models")
                .cloned()
                .unwrap_or(Json::Obj(BTreeMap::new())),
            bands: j.get("bands").cloned(),
        },
        "perf_ack" => Response::PerfAck {
            merged: get_u64(&j, "merged")?,
        },
        "shards" => {
            let arr = j
                .get("shards")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing 'shards'"))?;
            let mut shards = Vec::new();
            for d in arr {
                shards.push(ShardDesc {
                    addr: get_str(d, "addr")?,
                    healthy: matches!(d.get("healthy"), Some(Json::Bool(true))),
                    draining: matches!(d.get("draining"), Some(Json::Bool(true))),
                    inflight: get_u64(d, "inflight")?,
                    requests_ok: get_u64(d, "requests_ok")?,
                });
            }
            Response::Shards { shards }
        }
        "drained" => Response::Drained {
            shard: get_str(&j, "shard")?,
        },
        "autoscale" => {
            let mut contexts = Vec::new();
            if let Some(arr) = j.get("contexts").and_then(Json::as_arr) {
                for c in arr {
                    contexts.push(AutoscaleCtxDesc {
                        name: get_str(c, "name")?,
                        workers: get_u64(c, "workers").unwrap_or(0),
                        home: get_u64(c, "home").unwrap_or(0),
                        min: get_u64(c, "min").unwrap_or(0),
                        max: get_u64(c, "max").unwrap_or(0),
                        queue_depth: get_u64(c, "queue_depth").unwrap_or(0),
                        slo_ms: get_f64(c, "slo_ms").unwrap_or(0.0),
                    });
                }
            }
            Response::Autoscale(AutoscaleResp {
                enabled: matches!(j.get("enabled"), Some(Json::Bool(true))),
                policy: get_str(&j, "policy").unwrap_or_default(),
                moves: get_u64(&j, "moves").unwrap_or(0),
                moved_workers: get_u64(&j, "moved_workers").unwrap_or(0),
                last_action: get_str(&j, "last_action").ok(),
                contexts,
                shards: get_u64(&j, "shards").unwrap_or(0),
                shards_spawned: get_u64(&j, "shards_spawned").unwrap_or(0),
                shards_retired: get_u64(&j, "shards_retired").unwrap_or(0),
            })
        }
        "shutdown" => Response::Shutdown,
        "bye" => Response::Bye,
        other => bail!("unknown response type '{other}'"),
    })
}

/// ndjson decode (one line).
pub fn decode_response(line: &str) -> Result<Response> {
    let j = json::parse(line.trim()).map_err(|e| anyhow!("bad response json: {e}"))?;
    response_from_value(&j)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: Request) {
        let line = encode_request(&r);
        let back = decode_request(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(back, r, "{line}");
    }

    fn roundtrip_resp(r: Response) {
        let line = encode_response(&r);
        let back = decode_response(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(back, r, "{line}");
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(Request::Hello {
            client: "client-1".into(),
            policy: None,
            slo_ms: None,
            framing: None,
        });
        roundtrip_req(Request::Hello {
            client: "client-2".into(),
            policy: Some("epsilon:0.2".into()),
            slo_ms: Some(12.5),
            framing: Some("binary".into()),
        });
        roundtrip_req(Request::Submit(SubmitReq {
            id: 42,
            app: "matmul".into(),
            size: 64,
            tasks: 3,
            ctx: Some("gpu".into()),
            seed: 7,
            variant: Some("omp".into()),
            verify: true,
            trace: 9001,
        }));
        roundtrip_req(Request::Submit(SubmitReq {
            id: 0,
            app: "nw".into(),
            size: 32,
            tasks: 1,
            ctx: None,
            seed: 0,
            variant: None,
            verify: false,
            trace: 0,
        }));
        roundtrip_req(Request::Stats);
        roundtrip_req(Request::Contexts);
        roundtrip_req(Request::AutoscaleStatus);
        roundtrip_req(Request::Shutdown);
        roundtrip_req(Request::Quit);
    }

    #[test]
    fn autoscale_response_roundtrips() {
        roundtrip_resp(Response::Autoscale(AutoscaleResp::default()));
        roundtrip_resp(Response::Autoscale(AutoscaleResp {
            enabled: true,
            policy: "threshold".into(),
            moves: 3,
            moved_workers: 5,
            last_action: Some("moved 2 worker(s) beta -> alpha".into()),
            contexts: vec![AutoscaleCtxDesc {
                name: "alpha".into(),
                workers: 4,
                home: 2,
                min: 1,
                max: 6,
                queue_depth: 11,
                slo_ms: 25.0,
            }],
            shards: 3,
            shards_spawned: 1,
            shards_retired: 0,
        }));
    }

    #[test]
    fn cluster_request_roundtrips() {
        roundtrip_req(Request::PerfPull);
        let mut bucket = BTreeMap::new();
        bucket.insert("count".to_string(), Json::Num(3.0));
        bucket.insert("mean".to_string(), Json::Num(0.25));
        let mut sizes = BTreeMap::new();
        sizes.insert("64".to_string(), Json::Obj(bucket));
        let mut models = BTreeMap::new();
        models.insert("mmul:omp".to_string(), Json::Obj(sizes));
        roundtrip_req(Request::PerfPush {
            models: Json::Obj(models.clone()),
            bands: None,
        });
        // v8: selection-band summaries ride the same push
        roundtrip_req(Request::PerfPush {
            models: Json::Obj(models),
            bands: Some(Json::Arr(vec![Json::Str("band".into())])),
        });
        // a push without models decodes to an empty overlay
        match decode_request(r#"{"op":"perf_push"}"#).unwrap() {
            Request::PerfPush { models, bands } => {
                assert_eq!(models, Json::Obj(BTreeMap::new()));
                assert!(bands.is_none());
            }
            other => panic!("{other:?}"),
        }
        roundtrip_req(Request::Shards);
        roundtrip_req(Request::DrainShard {
            shard: "127.0.0.1:7201".into(),
        });
        assert!(decode_request(r#"{"op":"drain_shard"}"#).is_err());
    }

    #[test]
    fn cluster_response_roundtrips() {
        roundtrip_resp(Response::PerfModels {
            models: Json::Obj(BTreeMap::new()),
            bands: None,
        });
        roundtrip_resp(Response::PerfModels {
            models: Json::Obj(BTreeMap::new()),
            bands: Some(Json::Arr(Vec::new())),
        });
        roundtrip_resp(Response::PerfAck { merged: 12 });
        roundtrip_resp(Response::Shards {
            shards: vec![
                ShardDesc {
                    addr: "127.0.0.1:7201".into(),
                    healthy: true,
                    draining: false,
                    inflight: 3,
                    requests_ok: 99,
                },
                ShardDesc {
                    addr: "127.0.0.1:7202".into(),
                    healthy: false,
                    draining: true,
                    inflight: 0,
                    requests_ok: 0,
                },
            ],
        });
        roundtrip_resp(Response::Drained {
            shard: "127.0.0.1:7201".into(),
        });
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(Response::Hello {
            session: 9,
            version: PROTOCOL_VERSION,
            slo_ms: None,
            framing: None,
        });
        roundtrip_resp(Response::Hello {
            session: 9,
            version: PROTOCOL_VERSION,
            slo_ms: Some(40.0),
            framing: Some("binary".into()),
        });
        roundtrip_resp(Response::Result(ResultResp {
            id: 42,
            app: "matmul".into(),
            size: 64,
            ctx: "alpha".into(),
            policy: "greedy".into(),
            variants: vec!["omp".into(), "seq".into()],
            workers: vec![0, 3],
            batch: 4,
            modeled: 0.0025,
            wall: 0.001,
            rel_err: 1.5e-6,
            trace: 77,
        }));
        roundtrip_resp(Response::Error {
            id: Some(3),
            error: "queue \"full\"\nretry later".into(),
        });
        roundtrip_resp(Response::Error {
            id: None,
            error: "bad json".into(),
        });
        let mut ctx_tasks = BTreeMap::new();
        ctx_tasks.insert("alpha".to_string(), 10u64);
        ctx_tasks.insert("beta".to_string(), 4u64);
        let mut ctx_variants = BTreeMap::new();
        let mut alpha_hist = BTreeMap::new();
        alpha_hist.insert("omp".to_string(), 7u64);
        alpha_hist.insert("cuda".to_string(), 3u64);
        ctx_variants.insert("alpha".to_string(), alpha_hist);
        roundtrip_resp(Response::Stats(StatsResp {
            uptime: 12.5,
            requests_ok: 100,
            requests_err: 2,
            inflight: 3,
            tasks_executed: 250,
            queue_depth: 7,
            busy_workers: 4,
            total_workers: 5,
            sessions: 9,
            ctx_tasks,
            ctx_variants,
            slo_ms: 25.0,
            streams: 2,
            plans: 3,
            planned_tasks: 18,
            tasks_completed: 260,
            bytes_transferred: 1 << 20,
            batches_fused: 5,
            decisions: 300,
        }));
        roundtrip_resp(Response::Contexts {
            contexts: vec![CtxDesc {
                id: 1,
                name: "alpha".into(),
                policy: "dmda".into(),
                selector: "epsilon:0.1".into(),
                workers: vec![0, 1],
                queued: 2,
            }],
        });
        roundtrip_resp(Response::Shutdown);
        roundtrip_resp(Response::Bye);
    }

    #[test]
    fn stats_without_snapshot_fields_decode_as_zero() {
        // pre-v4 peers omit the runtime-snapshot fields, pre-v6 peers
        // the slo_ms/streams pair; decode them as zero rather than
        // failing the whole stats reply
        let line = r#"{"ok":true,"type":"stats","uptime":1,"requests_ok":2,
            "requests_err":0,"inflight":0,"tasks_executed":4}"#
            .replace('\n', "");
        match decode_response(&line).unwrap() {
            Response::Stats(s) => {
                assert_eq!(s.queue_depth, 0);
                assert_eq!(s.busy_workers, 0);
                assert_eq!(s.total_workers, 0);
                assert_eq!(s.sessions, 0);
                assert_eq!(s.tasks_executed, 4);
                assert_eq!(s.slo_ms, 0.0);
                assert_eq!(s.streams, 0);
                assert_eq!(s.plans, 0);
                assert_eq!(s.planned_tasks, 0);
                // v8 peers omit the v9 monotonic totals too
                assert_eq!(s.tasks_completed, 0);
                assert_eq!(s.bytes_transferred, 0);
                assert_eq!(s.batches_fused, 0);
                assert_eq!(s.decisions, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stream_request_roundtrips() {
        roundtrip_req(Request::StreamOpen(StreamOpenReq {
            id: 1,
            app: "sort".into(),
            size: 16384,
            stages: 2,
            window: 4,
            slide: 2,
            ctx: Some("hot".into()),
            slo_ms: Some(40.0),
            trace: 301,
        }));
        roundtrip_req(Request::StreamOpen(StreamOpenReq {
            id: 2,
            app: "matmul".into(),
            size: 48,
            stages: 1,
            window: 0,
            slide: 0,
            ctx: None,
            slo_ms: None,
            trace: 0,
        }));
        roundtrip_req(Request::StreamChunk {
            stream: 1,
            seq: 17,
            seed: 99,
        });
        roundtrip_req(Request::StreamClose { stream: 1 });
    }

    #[test]
    fn stream_open_defaults() {
        // minimal declaration: stages floors to 1, no window, no slide
        let r =
            decode_request(r#"{"op":"stream_open","id":5,"app":"sort","size":256}"#).unwrap();
        match r {
            Request::StreamOpen(q) => {
                assert_eq!(q.stages, 1);
                assert_eq!(q.window, 0);
                assert_eq!(q.slide, 0);
                assert!(q.ctx.is_none() && q.slo_ms.is_none());
            }
            other => panic!("{other:?}"),
        }
        // chunk without a seed defaults to 0
        match decode_request(r#"{"op":"stream_chunk","stream":5,"seq":1}"#).unwrap() {
            Request::StreamChunk { seed, .. } => assert_eq!(seed, 0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stream_response_roundtrips() {
        roundtrip_resp(Response::StreamOpened(StreamOpenedResp {
            stream: 1,
            credit: 8,
            window: 4,
            slide: 2,
            slo_ms: Some(40.0),
        }));
        roundtrip_resp(Response::StreamOpened(StreamOpenedResp {
            stream: 2,
            credit: 8,
            window: 0,
            slide: 0,
            slo_ms: None,
        }));
        roundtrip_resp(Response::StreamAck(StreamAckResp {
            stream: 1,
            seq: 9,
            ctx: "hot".into(),
            variants: vec!["cuda".into(), "omp".into()],
            workers: vec![1, 0],
            modeled: 0.0004,
            wall: 0.002,
            latency: 0.0035,
            credit: 4,
            shed: 1,
        }));
        roundtrip_resp(Response::StreamCredit(StreamCreditResp {
            stream: 1,
            credit: 2,
            shed: 2,
            queued_ms: 31.5,
        }));
        roundtrip_resp(Response::StreamClosed(StreamClosedResp {
            stream: 1,
            chunks: 120,
            dropped: 0,
            windows: 30,
            shed_windows: 6,
            credit_signals: 4,
            p95_ms: 18.25,
        }));
    }

    #[test]
    fn stream_decode_is_tolerant_and_rejects_malformed() {
        // acks from a peer that omits optional detail still decode
        let line = r#"{"ok":true,"type":"stream_ack","stream":1,"seq":2,"credit":8}"#;
        match decode_response(line).unwrap() {
            Response::StreamAck(a) => {
                assert!(a.variants.is_empty() && a.workers.is_empty());
                assert_eq!(a.shed, 0);
            }
            other => panic!("{other:?}"),
        }
        // the stream id itself is not optional
        assert!(decode_request(r#"{"op":"stream_chunk","seq":1}"#).is_err());
        assert!(decode_request(r#"{"op":"stream_close"}"#).is_err());
        assert!(decode_request(r#"{"op":"stream_open","id":1,"app":"sort"}"#).is_err());
        assert!(decode_response(r#"{"ok":true,"type":"stream_credit","credit":1}"#).is_err());
    }

    #[test]
    fn submit_defaults() {
        let r = decode_request(r#"{"op":"submit","id":1,"app":"sort","size":256}"#).unwrap();
        match r {
            Request::Submit(q) => {
                assert_eq!(q.tasks, 1);
                assert_eq!(q.seed, 0);
                assert!(q.verify);
                assert!(q.ctx.is_none() && q.variant.is_none());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode_request("not json").is_err());
        assert!(decode_request(r#"{"op":"nope"}"#).is_err());
        assert!(decode_request(r#"{"op":"submit","id":1}"#).is_err());
        assert!(decode_response(r#"{"ok":true}"#).is_err());
    }

    #[test]
    fn pre_v7_hello_decodes_without_framing() {
        match decode_request(r#"{"op":"hello","client":"old"}"#).unwrap() {
            Request::Hello { framing, .. } => assert!(framing.is_none()),
            other => panic!("{other:?}"),
        }
        let old = decode_response(r#"{"ok":true,"type":"hello","session":1,"version":6}"#);
        match old.unwrap() {
            Response::Hello { framing, .. } => assert!(framing.is_none()),
            other => panic!("{other:?}"),
        }
    }

    /// One representative of every request kind (for cross-framing
    /// property tests).
    fn all_request_kinds() -> Vec<Request> {
        vec![
            Request::Hello {
                client: "c".into(),
                policy: Some("epsilon:0.1".into()),
                slo_ms: Some(25.0),
                framing: Some("binary".into()),
            },
            Request::Submit(SubmitReq {
                id: 7,
                app: "matmul".into(),
                size: 48,
                tasks: 2,
                ctx: Some("hot".into()),
                seed: 3,
                variant: Some("omp".into()),
                verify: true,
                trace: 12,
            }),
            Request::SubmitGraph(SubmitGraphReq {
                id: 9,
                nodes: vec![
                    GraphNodeReq {
                        name: "load".into(),
                        app: "sort".into(),
                        size: 4096,
                        deps: vec![],
                        variant: None,
                    },
                    GraphNodeReq {
                        name: "reduce".into(),
                        app: "sort".into(),
                        size: 4096,
                        deps: vec!["load".into()],
                        variant: Some("cuda".into()),
                    },
                ],
                ctx: Some("hot".into()),
                mode: Some("greedy".into()),
                trace: 13,
            }),
            Request::StreamOpen(StreamOpenReq {
                id: 1,
                app: "sort".into(),
                size: 4096,
                stages: 2,
                window: 4,
                slide: 2,
                ctx: None,
                slo_ms: Some(40.0),
                trace: 14,
            }),
            Request::StreamChunk {
                stream: 1,
                seq: 5,
                seed: 11,
            },
            Request::StreamClose { stream: 1 },
            Request::Stats,
            Request::Metrics {
                format: Some("prometheus".into()),
            },
            Request::Decisions {
                limit: Some(32),
                codelet: Some("mmul".into()),
            },
            Request::DumpTrace,
            Request::Contexts,
            Request::AutoscaleStatus,
            Request::PerfPull,
            Request::PerfPush {
                models: Json::Obj(BTreeMap::new()),
                bands: Some(Json::Arr(Vec::new())),
            },
            Request::Shards,
            Request::DrainShard {
                shard: "shard0".into(),
            },
            Request::Shutdown,
            Request::Quit,
        ]
    }

    /// One representative of every response kind.
    fn all_response_kinds() -> Vec<Response> {
        vec![
            Response::Hello {
                session: 1,
                version: PROTOCOL_VERSION,
                slo_ms: Some(25.0),
                framing: Some("binary".into()),
            },
            Response::Result(ResultResp {
                id: 7,
                app: "matmul".into(),
                size: 48,
                ctx: "hot".into(),
                policy: "greedy".into(),
                variants: vec!["omp".into()],
                workers: vec![2],
                batch: 1,
                modeled: 0.5,
                wall: 0.25,
                rel_err: 0.0,
                trace: 12,
            }),
            Response::GraphDone(GraphDoneResp {
                id: 9,
                ctx: "hot".into(),
                mode: "planned".into(),
                makespan: 0.012,
                wall: 0.015,
                elided_transfers: 1,
                nodes: vec![GraphNodeReport {
                    name: "reduce".into(),
                    variant: "cuda".into(),
                    arch: "cuda".into(),
                    planned: true,
                    est: 0.004,
                    modeled: 0.004,
                    wall: 0.005,
                    elided: true,
                }],
            }),
            Response::StreamOpened(StreamOpenedResp {
                stream: 1,
                credit: 8,
                window: 4,
                slide: 2,
                slo_ms: None,
            }),
            Response::StreamAck(StreamAckResp {
                stream: 1,
                seq: 5,
                ctx: "hot".into(),
                variants: vec!["cuda".into()],
                workers: vec![3],
                modeled: 0.1,
                wall: 0.2,
                latency: 0.3,
                credit: 4,
                shed: 1,
            }),
            Response::StreamCredit(StreamCreditResp {
                stream: 1,
                credit: 2,
                shed: 2,
                queued_ms: 9.5,
            }),
            Response::StreamClosed(StreamClosedResp {
                stream: 1,
                chunks: 10,
                dropped: 0,
                windows: 3,
                shed_windows: 1,
                credit_signals: 2,
                p95_ms: 8.0,
            }),
            Response::Error {
                id: Some(7),
                error: "boom".into(),
            },
            Response::Stats(StatsResp {
                uptime: 1.0,
                requests_ok: 2,
                requests_err: 0,
                inflight: 1,
                tasks_executed: 4,
                queue_depth: 0,
                busy_workers: 1,
                total_workers: 4,
                sessions: 1,
                ctx_tasks: BTreeMap::new(),
                ctx_variants: BTreeMap::new(),
                slo_ms: 0.0,
                streams: 0,
                plans: 0,
                planned_tasks: 0,
                tasks_completed: 4,
                bytes_transferred: 4096,
                batches_fused: 1,
                decisions: 6,
            }),
            Response::Metrics(MetricsResp {
                metrics: {
                    let mut counters = BTreeMap::new();
                    counters.insert("select_decisions_total".to_string(), Json::Num(6.0));
                    let mut m = BTreeMap::new();
                    m.insert("counters".to_string(), Json::Obj(counters));
                    Json::Obj(m)
                },
                text: Some("# TYPE select_decisions_total counter\n".into()),
            }),
            Response::Decisions(DecisionsResp {
                total: 6,
                dropped: 0,
                evicted: 2,
                decisions: Json::Arr(vec![Json::Obj(BTreeMap::new())]),
            }),
            Response::DumpTrace(TraceResp {
                events: 3,
                trace: {
                    let mut m = BTreeMap::new();
                    m.insert("traceEvents".to_string(), Json::Arr(Vec::new()));
                    Json::Obj(m)
                },
            }),
            Response::Contexts {
                contexts: vec![CtxDesc {
                    id: 0,
                    name: "default".into(),
                    policy: "fifo".into(),
                    selector: "greedy".into(),
                    workers: vec![0, 1],
                    queued: 0,
                }],
            },
            Response::PerfModels {
                models: Json::Obj(BTreeMap::new()),
                bands: Some(Json::Arr(Vec::new())),
            },
            Response::PerfAck { merged: 3 },
            Response::Shards {
                shards: vec![ShardDesc {
                    addr: "127.0.0.1:7201".into(),
                    healthy: true,
                    draining: false,
                    inflight: 0,
                    requests_ok: 1,
                }],
            },
            Response::Drained {
                shard: "shard0".into(),
            },
            Response::Autoscale(AutoscaleResp::default()),
            Response::Shutdown,
            Response::Bye,
        ]
    }

    #[test]
    fn binary_framing_roundtrips_every_request_kind() {
        use crate::serve::transport::codec::{encode_frame, FrameDecoder, Framing};
        for req in all_request_kinds() {
            for framing in [Framing::Ndjson, Framing::Binary] {
                let mut wire = Vec::new();
                encode_frame(framing, &request_value(&req), &mut wire);
                let mut dec = FrameDecoder::new(framing);
                dec.push(&wire);
                let v = dec.next().unwrap().expect("one frame");
                let back = request_from_value(&v)
                    .unwrap_or_else(|e| panic!("{req:?} via {framing:?}: {e}"));
                assert_eq!(back, req, "{framing:?}");
                assert_eq!(dec.buffered(), 0);
            }
        }
    }

    #[test]
    fn binary_framing_roundtrips_every_response_kind() {
        use crate::serve::transport::codec::{encode_frame, FrameDecoder, Framing};
        for resp in all_response_kinds() {
            for framing in [Framing::Ndjson, Framing::Binary] {
                let mut wire = Vec::new();
                encode_frame(framing, &response_value(&resp), &mut wire);
                let mut dec = FrameDecoder::new(framing);
                dec.push(&wire);
                let v = dec.next().unwrap().expect("one frame");
                let back = response_from_value(&v)
                    .unwrap_or_else(|e| panic!("{resp:?} via {framing:?}: {e}"));
                assert_eq!(back, resp, "{framing:?}");
                assert_eq!(dec.buffered(), 0);
            }
        }
    }

    #[test]
    fn binary_framing_survives_fragmented_delivery() {
        // The whole message set concatenated on one wire, delivered in
        // 3-byte fragments: every kind must resurface intact, in order.
        use crate::serve::transport::codec::{encode_frame, FrameDecoder, Framing};
        let reqs = all_request_kinds();
        let mut wire = Vec::new();
        for req in &reqs {
            encode_frame(Framing::Binary, &request_value(req), &mut wire);
        }
        let mut dec = FrameDecoder::new(Framing::Binary);
        let mut got = Vec::new();
        for chunk in wire.chunks(3) {
            dec.push(chunk);
            while let Some(v) = dec.next().unwrap() {
                got.push(request_from_value(&v).unwrap());
            }
        }
        assert_eq!(got, reqs);
    }

    #[test]
    fn binary_framing_survives_fragmented_response_delivery() {
        // Same property on the response side: every kind (including the
        // v8 graph_done report) concatenated and fed back one byte at a
        // time must resurface intact, in order.
        use crate::serve::transport::codec::{encode_frame, FrameDecoder, Framing};
        let resps = all_response_kinds();
        let mut wire = Vec::new();
        for resp in &resps {
            encode_frame(Framing::Binary, &response_value(resp), &mut wire);
        }
        let mut dec = FrameDecoder::new(Framing::Binary);
        let mut got = Vec::new();
        for chunk in wire.chunks(1) {
            dec.push(chunk);
            while let Some(v) = dec.next().unwrap() {
                got.push(response_from_value(&v).unwrap());
            }
        }
        assert_eq!(got, resps);
    }

    #[test]
    fn graph_request_roundtrips() {
        // every SubmitGraph field, with and without optionals
        roundtrip_req(Request::SubmitGraph(SubmitGraphReq {
            id: 31,
            nodes: vec![
                GraphNodeReq {
                    name: "src".into(),
                    app: "sort".into(),
                    size: 65536,
                    deps: vec![],
                    variant: Some("omp".into()),
                },
                GraphNodeReq {
                    name: "mid".into(),
                    app: "sort".into(),
                    size: 65536,
                    deps: vec!["src".into()],
                    variant: None,
                },
                GraphNodeReq {
                    name: "sink".into(),
                    app: "sort".into(),
                    size: 65536,
                    deps: vec!["src".into(), "mid".into()],
                    variant: None,
                },
            ],
            ctx: Some("pipeline".into()),
            mode: Some("planned".into()),
            trace: 41,
        }));
        roundtrip_req(Request::SubmitGraph(SubmitGraphReq {
            id: 32,
            nodes: vec![GraphNodeReq {
                name: "only".into(),
                app: "matmul".into(),
                size: 48,
                deps: vec![],
                variant: None,
            }],
            ctx: None,
            mode: None,
            trace: 0,
        }));
        // malformed: node list required and non-empty, nodes need names
        assert!(decode_request(r#"{"op":"submit_graph","id":1}"#).is_err());
        assert!(decode_request(r#"{"op":"submit_graph","id":1,"nodes":[]}"#).is_err());
        assert!(
            decode_request(r#"{"op":"submit_graph","id":1,"nodes":[{"app":"sort","size":8}]}"#)
                .is_err()
        );
    }

    #[test]
    fn graph_response_roundtrips() {
        // every GraphDone field, both planned and degraded-to-greedy
        roundtrip_resp(Response::GraphDone(GraphDoneResp {
            id: 31,
            ctx: "pipeline".into(),
            mode: "planned".into(),
            makespan: 0.0421,
            wall: 0.0533,
            elided_transfers: 2,
            nodes: vec![
                GraphNodeReport {
                    name: "src".into(),
                    variant: "omp".into(),
                    arch: "cpu".into(),
                    planned: true,
                    est: 0.01,
                    modeled: 0.011,
                    wall: 0.012,
                    elided: false,
                },
                GraphNodeReport {
                    name: "sink".into(),
                    variant: "cuda".into(),
                    arch: "cuda".into(),
                    planned: true,
                    est: 0.004,
                    modeled: 0.0041,
                    wall: 0.0039,
                    elided: true,
                },
            ],
        }));
        roundtrip_resp(Response::GraphDone(GraphDoneResp {
            id: 32,
            ctx: "default".into(),
            mode: "greedy".into(),
            makespan: 0.0,
            wall: 0.001,
            elided_transfers: 0,
            nodes: vec![],
        }));
        // malformed: node reports need name and variant
        assert!(decode_response(
            r#"{"ok":true,"type":"graph_done","id":1,"mode":"planned","nodes":[{"variant":"omp"}]}"#
        )
        .is_err());
        assert!(decode_response(r#"{"ok":true,"type":"graph_done","id":1}"#).is_err());
    }

    #[test]
    fn observability_request_roundtrips() {
        roundtrip_req(Request::Metrics { format: None });
        roundtrip_req(Request::Metrics {
            format: Some("prometheus".into()),
        });
        roundtrip_req(Request::Decisions {
            limit: None,
            codelet: None,
        });
        roundtrip_req(Request::Decisions {
            limit: Some(16),
            codelet: Some("sort".into()),
        });
        roundtrip_req(Request::DumpTrace);
        // bare scrapes decode with every option absent
        match decode_request(r#"{"op":"metrics"}"#).unwrap() {
            Request::Metrics { format } => assert!(format.is_none()),
            other => panic!("{other:?}"),
        }
        match decode_request(r#"{"op":"decisions"}"#).unwrap() {
            Request::Decisions { limit, codelet } => {
                assert!(limit.is_none() && codelet.is_none());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn observability_response_roundtrips() {
        let mut counters = BTreeMap::new();
        counters.insert("serve_requests_total".to_string(), Json::Num(42.0));
        let mut reg = BTreeMap::new();
        reg.insert("counters".to_string(), Json::Obj(counters));
        roundtrip_resp(Response::Metrics(MetricsResp {
            metrics: Json::Obj(reg.clone()),
            text: None,
        }));
        roundtrip_resp(Response::Metrics(MetricsResp {
            metrics: Json::Obj(reg),
            text: Some("serve_requests_total 42\n".into()),
        }));
        roundtrip_resp(Response::Decisions(DecisionsResp {
            total: 9,
            dropped: 1,
            evicted: 3,
            decisions: Json::Arr(vec![Json::Obj(BTreeMap::new())]),
        }));
        roundtrip_resp(Response::DumpTrace(TraceResp {
            events: 2,
            trace: {
                let mut m = BTreeMap::new();
                m.insert("traceEvents".to_string(), Json::Arr(Vec::new()));
                Json::Obj(m)
            },
        }));
        // tolerant decode: a sparse metrics reply still lands
        match decode_response(r#"{"ok":true,"type":"metrics"}"#).unwrap() {
            Response::Metrics(m) => {
                assert_eq!(m.metrics, Json::Obj(BTreeMap::new()));
                assert!(m.text.is_none());
            }
            other => panic!("{other:?}"),
        }
        match decode_response(r#"{"ok":true,"type":"decisions"}"#).unwrap() {
            Response::Decisions(d) => {
                assert_eq!(d.total, 0);
                assert_eq!(d.decisions, Json::Arr(Vec::new()));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn v8_peer_messages_decode_without_trace() {
        // a v8 peer omits `trace` on submit-family requests and results
        match decode_request(r#"{"op":"submit","id":1,"app":"sort","size":256}"#).unwrap() {
            Request::Submit(q) => assert_eq!(q.trace, 0),
            other => panic!("{other:?}"),
        }
        let line = r#"{"op":"stream_open","id":5,"app":"sort","size":256}"#;
        match decode_request(line).unwrap() {
            Request::StreamOpen(q) => assert_eq!(q.trace, 0),
            other => panic!("{other:?}"),
        }
        let line = r#"{"ok":true,"type":"result","id":1,"app":"sort","size":256,
            "ctx":"default","policy":"greedy","variants":["omp"],"workers":[0],
            "batch":1,"modeled":0.1,"wall":0.1,"rel_err":0}"#
            .replace('\n', "");
        match decode_response(&line).unwrap() {
            Response::Result(r) => assert_eq!(r.trace, 0),
            other => panic!("{other:?}"),
        }
        // and a v8 peer rejects nothing it used to accept: a v9 client
        // sending trace=0 omits the field entirely
        let wire = encode_request(&Request::Submit(SubmitReq {
            id: 1,
            app: "sort".into(),
            size: 256,
            tasks: 1,
            ctx: None,
            seed: 0,
            variant: None,
            verify: true,
            trace: 0,
        }));
        assert!(!wire.contains("trace"));
    }
}

