//! `serve` — the multi-tenant component service (`compar serve`).
//!
//! The paper's runtime selects implementation variants per call; this
//! layer turns the one-shot benchmark runtime into a *persistent
//! service*: many concurrent clients submit task-graph requests over a
//! newline-delimited JSON protocol, each request is routed to a
//! **scheduling context** (a worker partition with its own scheduler
//! and [`crate::taskrt::selection::SelectionPolicy`] — see
//! [`crate::taskrt::Runtime::create_context_with`]), same-codelet
//! requests are batched, an admission gate bounds in-flight work, and
//! shutdown drains gracefully. All contexts share one data registry,
//! one performance-model store and one XLA service, so variant
//! selection keeps learning across tenants — the optimized-composition
//! setting where history-based selection pays off most. Sessions can
//! pick their own selection policy in the hello handshake, clients can
//! pipeline requests (correlation ids match out-of-order replies), and
//! stats report per-context selection histograms.
//!
//! Protocol v6 adds **stream sessions** (see [`crate::stream`]): a
//! client opens a long-lived chunk pipeline (`stream_open`), pushes
//! chunks through it under credit-based flow control, and every
//! chunk's stage selects its variant per-chunk — with SLO-driven
//! backpressure shedding window granularity instead of chunks.
//!
//! Protocol v7 adds the **multiplexed transport** (see [`transport`]):
//! the server can run a readiness event loop (`--transport epoll`)
//! multiplexing thousands of non-blocking sessions per core, and every
//! session negotiates a framing in `hello` — newline-delimited JSON
//! (default) or compact length-prefixed binary — with pooled buffers
//! and coalesced vectored writes on the hot path.
//!
//! Protocol v8 adds **graph submission** (see [`crate::plan`]): a
//! client ships a whole task DAG in one `submit_graph` request, the
//! [`crate::plan::GraphPlanner`] assigns variants to every node
//! jointly before release, and the `graph_done` report carries each
//! node's variant, arch, modeled vs wall timing and elided
//! producer→consumer transfers.
//!
//! Protocol v9 adds the **observability plane** (see [`crate::obs`]):
//! a `metrics` request scrapes the runtime's registry (counters,
//! gauges, latency histograms — JSON or Prometheus-style text), a
//! `decisions` request returns the selection-decision audit ring
//! (query snapshot, candidate estimates, chosen variant, reason tag
//! per decision), and `dump_trace` flushes the live span ring as
//! Chrome Trace Event Format. Every request carries a trace id —
//! minted at admission when the client sends none — that rides
//! client → router → shard → task → result.
//!
//! Layers (each its own module):
//! * [`protocol`] — wire format (requests/responses, encode/decode).
//! * [`transport`] — framing codecs, buffer pool, readiness loop.
//! * [`server`] — sessions, admission, batching, contexts, drain.
//! * [`client`] — blocking client used by tools and tests.
//! * [`loadgen`] — the throughput/latency measurement harness.

pub mod client;
pub mod loadgen;
pub mod protocol;
pub mod server;
pub mod transport;

pub use client::{Client, ClientConfig};
pub use loadgen::{LoadProfile, LoadReport, LoadgenOptions};
pub use protocol::{
    DecisionsResp, GraphDoneResp, GraphNodeReport, GraphNodeReq, MetricsResp, Request, Response,
    ShardDesc, StreamAckResp, StreamClosedResp, StreamCreditResp, StreamOpenReq, StreamOpenedResp,
    SubmitGraphReq, SubmitReq, TraceResp,
};
pub use server::{parse_contexts, CtxSpec, ServeOptions, Server};
pub use transport::{Framing, TransportKind};
