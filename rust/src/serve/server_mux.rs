//! The multiplexed serve transport (`--transport epoll`): one thread
//! runs every session through a readiness event loop instead of a
//! thread per connection.
//!
//! Layout: token 0 is the self-wake channel, token 1 the listener,
//! tokens >= 2 are connections. Each connection owns a nonblocking
//! socket, a [`FrameDecoder`] fed from a pooled read buffer, and an
//! [`Outbox`] of encoded reply frames. Completion threads and stream
//! workers never touch a socket: they encode into pooled buffers,
//! queue on the outbox, and ring the [`WakeHub`]; the loop drains each
//! dirty outbox with one vectored write per readiness cycle.
//!
//! Admission backpressure is inherited unchanged: a submit that hits
//! the gate cap blocks *the loop itself*, pausing all reads — which is
//! exactly the pushback the threaded path applies per session, applied
//! globally. Completions release the gate from their own threads, and
//! the waker's nonblocking write guarantees they never deadlock
//! against the stalled loop.

use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;

use crate::serve::transport::buffer::BufferPool;
use crate::serve::transport::event_loop::{drain_wakes, WakeHub, Waker};
use crate::serve::transport::poller::{Event, Poller};

use super::*;

const TOKEN_WAKE: u64 = 0;
const TOKEN_LISTENER: u64 = 1;
const TOKEN_BASE: u64 = 2;

/// One multiplexed connection, owned by the loop thread.
struct Conn {
    stream: TcpStream,
    token: u64,
    sid: u64,
    dec: FrameDecoder,
    outbox: Arc<Outbox>,
    reply: ReplyLane,
    sess: SessionState,
    /// Whether writable interest is currently armed in the poller.
    want_write: bool,
    /// Close once the outbox drains (quit acked / protocol desync).
    closing: bool,
}

pub(super) fn event_loop(shared: Arc<Shared>, listener: TcpListener) {
    if let Err(e) = run(&shared, listener) {
        eprintln!("serve: event loop failed: {e:#}");
    }
}

struct Loop {
    poller: Poller,
    hub: Arc<WakeHub>,
    pool: Arc<BufferPool>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
}

fn run(shared: &Arc<Shared>, listener: TcpListener) -> Result<()> {
    let (waker, mut wake_rx) = Waker::pair().context("wake channel")?;
    let mut lp = Loop {
        poller: Poller::new_best(),
        hub: Arc::new(WakeHub::new(waker)),
        pool: Arc::new(BufferPool::serving_default()),
        conns: HashMap::new(),
        next_token: TOKEN_BASE,
    };
    lp.poller.register(wake_rx.as_raw_fd(), TOKEN_WAKE, false)?;
    lp.poller
        .register(listener.as_raw_fd(), TOKEN_LISTENER, false)?;
    let mut events: Vec<Event> = Vec::new();
    let mut dirty: Vec<u64> = Vec::new();
    while !shared.draining.load(Ordering::SeqCst) {
        events.clear();
        // 100ms cap mirrors the threaded path's read timeout: the loop
        // observes `draining` at the same cadence while fully idle
        lp.poller.wait(&mut events, 100)?;
        for ev in events.iter().copied() {
            match ev.token {
                TOKEN_WAKE => drain_wakes(&mut wake_rx),
                TOKEN_LISTENER => accept_ready(shared, &listener, &mut lp),
                tok => {
                    let mut dead = false;
                    if let Some(conn) = lp.conns.get_mut(&tok) {
                        if ev.readable || ev.hangup {
                            dead = !read_ready(shared, conn);
                        }
                        if !dead && (ev.writable || conn.outbox.pending()) {
                            dead = !flush_conn(conn, &mut lp.poller);
                        }
                        if !dead && conn.closing && !conn.outbox.pending() {
                            dead = true;
                        }
                    }
                    if dead {
                        close_conn(shared, &mut lp, tok);
                    }
                    if shared.draining.load(Ordering::SeqCst) {
                        break;
                    }
                }
            }
        }
        // connections whose outboxes gained frames off-loop (batch
        // completions, stream acks) since the last cycle
        dirty.clear();
        lp.hub.drain(&mut dirty);
        dirty.sort_unstable();
        dirty.dedup();
        for tok in dirty.drain(..) {
            let mut dead = false;
            if let Some(conn) = lp.conns.get_mut(&tok) {
                dead = !flush_conn(conn, &mut lp.poller);
                if !dead && conn.closing && !conn.outbox.pending() {
                    dead = true;
                }
            }
            if dead {
                close_conn(shared, &mut lp, tok);
            }
        }
    }
    // drain: flush what's queued best-effort, then tear every session
    // down with the same cleanup the threaded path runs
    let tokens: Vec<u64> = lp.conns.keys().copied().collect();
    for tok in tokens {
        close_conn(shared, &mut lp, tok);
    }
    Ok(())
}

fn accept_ready(shared: &Arc<Shared>, listener: &TcpListener, lp: &mut Loop) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                // final-flush path only; the loop never blocks on writes
                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                let sid = shared.next_session.fetch_add(1, Ordering::Relaxed);
                let token = lp.next_token;
                lp.next_token += 1;
                if lp.poller.register(stream.as_raw_fd(), token, false).is_err() {
                    continue;
                }
                let outbox = Outbox::new(token, lp.hub.clone(), lp.pool.clone());
                let reply: ReplyLane = Arc::new(ReplySink::Queued {
                    outbox: outbox.clone(),
                    framing: Mutex::new(Framing::Ndjson),
                });
                shared.rt.tenant_started();
                lp.conns.insert(
                    token,
                    Conn {
                        dec: FrameDecoder::with_buffer(Framing::Ndjson, lp.pool.take()),
                        stream,
                        token,
                        sid,
                        outbox,
                        reply,
                        sess: SessionState::default(),
                        want_write: false,
                        closing: false,
                    },
                );
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(_) => return,
        }
    }
}

/// Pull everything the socket has, dispatching each complete frame.
/// Returns false when the connection is finished (EOF / error).
fn read_ready(shared: &Arc<Shared>, conn: &mut Conn) -> bool {
    loop {
        loop {
            match conn.dec.next() {
                Ok(Some(v)) => {
                    let keep = handle_frame(shared, &conn.reply, &v, conn.sid, &mut conn.sess);
                    if conn.sess.framing != conn.dec.framing() {
                        conn.dec.set_framing(conn.sess.framing);
                    }
                    if !keep {
                        // stop reading; close once the bye is flushed
                        conn.closing = true;
                        return true;
                    }
                }
                Ok(None) => break,
                Err(e) => {
                    send_line(
                        &conn.reply,
                        &Response::Error {
                            id: None,
                            error: format!("{e:#}"),
                        },
                    );
                    conn.closing = true;
                    return true;
                }
            }
        }
        match conn.dec.fill_from(&mut conn.stream) {
            Ok(0) => return false, // EOF
            Ok(_) => {}
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
}

/// Drain the outbox as far as the socket accepts, arming or disarming
/// writable interest to match. Returns false on a dead peer.
fn flush_conn(conn: &mut Conn, poller: &mut Poller) -> bool {
    match conn.outbox.flush(&mut conn.stream) {
        Ok(drained) => {
            let want = !drained;
            if want != conn.want_write {
                conn.want_write = want;
                let _ = poller.modify(conn.stream.as_raw_fd(), conn.token, want);
            }
            true
        }
        Err(e) => {
            eprintln!(
                "serve: closing session {}, reply write failed: {e}",
                conn.sid
            );
            false
        }
    }
}

/// Deregister, final-flush (so quit/shutdown acks reach the peer),
/// close the outbox, and run the threaded path's session cleanup.
fn close_conn(shared: &Arc<Shared>, lp: &mut Loop, token: u64) {
    let Some(mut conn) = lp.conns.remove(&token) else {
        return;
    };
    let _ = lp.poller.deregister(conn.stream.as_raw_fd());
    if conn.outbox.pending() {
        // switch to blocking with the write deadline for the last mile
        if conn.stream.set_nonblocking(false).is_ok() {
            let _ = conn.outbox.flush(&mut conn.stream);
        }
    }
    conn.outbox.close();
    lp.pool.put(conn.dec.into_buffer());
    let _ = conn.stream.shutdown(std::net::Shutdown::Both);
    for (_, h) in std::mem::take(&mut conn.sess.streams) {
        close_stream(shared, h);
    }
    if let Some(a) = shared.autoscale.lock().unwrap().as_ref() {
        a.release_session(conn.sid);
    }
    shared.rt.tenant_finished();
}
