//! Shared scaffolding for the custom bench binaries (criterion is not
//! available in the offline image; util::stats provides the measurement
//! core). Each fig1_* bench regenerates one panel of the paper's Fig. 1.

use std::sync::Arc;

use compar::bench_harness::fig1;
use compar::runtime::Manifest;

/// Run one Fig. 1 panel and print it. `quick` trims reps for CI runs.
pub fn run_fig1(app: &str) {
    let quick = std::env::args().any(|a| a == "--quick");
    let manifest = Manifest::load(&compar::runtime::manifest::default_dir())
        .ok()
        .map(Arc::new);
    if manifest.is_none() {
        eprintln!("(no artifacts: all rows model-derived; run `make artifacts`)");
    }
    let (reps, max_meas) = if quick { (1, 64) } else { (3, 256) };
    match fig1::series(app, manifest.as_ref(), reps, max_meas) {
        Ok(points) => {
            println!("{}", fig1::render(app, &points));
            if app == "matmul" {
                println!("{}", fig1::matmul_variant_table());
            }
        }
        Err(e) => {
            eprintln!("bench failed: {e:#}");
            std::process::exit(1);
        }
    }
}
