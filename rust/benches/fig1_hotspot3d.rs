//! Regenerates the paper's Fig. 1 panel for hotspot3d (cargo bench --bench fig1_hotspot3d).
mod common;

fn main() {
    common::run_fig1("hotspot3d");
}
