//! Regenerates the paper's Fig. 1 panel for hotspot (cargo bench --bench fig1_hotspot).
mod common;

fn main() {
    common::run_fig1("hotspot");
}
