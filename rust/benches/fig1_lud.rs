//! Regenerates the paper's Fig. 1 panel for lud (cargo bench --bench fig1_lud).
mod common;

fn main() {
    common::run_fig1("lud");
}
