//! Ablation benches for the design choices DESIGN.md calls out:
//!  A1 scheduler policy — makespan of a mixed task stream per policy;
//!  A2 data awareness  — dmda with vs without the transfer-cost term
//!     on a transfer-heavy ping-pong workload;
//!  A3 calibration     — selection accuracy as models warm up;
//!  A4 variant pruning — cold-phase length with vs without the
//!     compile-time pruning pass (paper §5 future work).

use std::sync::Arc;

use compar::apps;
use compar::bench_harness::selection::oracle_variant;
use compar::runtime::Manifest;
use compar::taskrt::{Config, Runtime, SchedPolicy};
use compar::util::stats::fmt_time;

fn manifest() -> Option<Arc<Manifest>> {
    Manifest::load(&compar::runtime::manifest::default_dir())
        .ok()
        .map(Arc::new)
}

/// A1: mixed stream of all apps, modeled makespan per scheduler.
fn a1_scheduler_policies(m: &Arc<Manifest>) {
    println!("-- A1: scheduler policy vs modeled total time (mixed stream) --");
    let stream: Vec<(&str, usize)> = vec![
        ("matmul", 128),
        ("hotspot", 128),
        ("sort", 1024),
        ("nw", 127),
        ("lud", 128),
        ("matmul", 256),
        ("hotspot", 256),
        ("sort", 4096),
    ];
    for sched in [
        SchedPolicy::Random,
        SchedPolicy::Eager,
        SchedPolicy::WorkStealing,
        SchedPolicy::Dmda,
        SchedPolicy::Heft,
    ] {
        let cfg = Config {
            ncpu: 2,
            ncuda: 1,
            sched,
            ..Config::default()
        };
        let rt = Runtime::new(cfg, Some(m.clone())).unwrap();
        // calibrate
        for (app, size) in &stream {
            let n = apps::codelet(app).unwrap().impls.len();
            for i in 0..(3 * n) {
                let _ = apps::run_once(&rt, app, *size, 100 + i as u64, None, false);
            }
        }
        rt.drain_results();
        for (i, (app, size)) in stream.iter().enumerate() {
            let _ = apps::run_once(&rt, app, *size, 900 + i as u64, None, false);
        }
        let total = rt.metrics().modeled_total();
        println!("   {:8} {:>12}", sched.name(), fmt_time(total));
    }
}

/// A2: data awareness — a workload where CPU and GPU execution times are
/// close, so the transfer term decides: tasks alternate between two
/// instances, one GPU-resident, one CPU-resident. The data-aware policy
/// keeps each task where its data lives; the ablated one bounces data
/// across PCIe.
fn a2_data_awareness(m: &Arc<Manifest>) {
    println!("\n-- A2: dmda transfer-model term (alternating shared-data tasks) --");
    for (label, data_aware) in [("dmda (data aware)", true), ("dm (no transfer term)", false)] {
        let cfg = Config {
            ncpu: 2,
            ncuda: 1,
            sched: SchedPolicy::Dmda,
            data_aware,
            ..Config::default()
        };
        let rt = Runtime::new(cfg, Some(m.clone())).unwrap();
        let cl = rt.register_codelet(apps::codelet("lud").unwrap());
        // calibrate on throwaway instances
        for i in 0..9 {
            let _ = apps::run_once(&rt, "lud", 256, 50 + i, None, false);
        }
        rt.drain_results();
        // two long-lived instances, interleaved tasks
        let inst_a = apps::prepare(&rt, "lud", 256, 1).unwrap();
        let inst_b = apps::prepare(&rt, "lud", 256, 2).unwrap();
        for i in 0..24 {
            let inst = if i % 2 == 0 { &inst_a } else { &inst_b };
            let spec = compar::taskrt::TaskSpec::new(cl.clone(), inst.handles.clone(), 256);
            rt.submit(spec).unwrap();
        }
        rt.wait_all().unwrap();
        let bytes = rt
            .metrics()
            .bytes_transferred
            .load(std::sync::atomic::Ordering::Relaxed);
        let total = rt.metrics().modeled_total();
        let hist = rt.metrics().variant_histogram();
        println!(
            "   {label:24} modeled {:>12}  PCIe bytes {:>9}  {hist:?}",
            fmt_time(total),
            bytes
        );
    }
}

/// A3: calibration curve — decision accuracy in windows of 5 tasks.
fn a3_calibration(m: &Arc<Manifest>) {
    println!("\n-- A3: dmda selection accuracy while models warm (matmul 128) --");
    let cfg = Config {
        ncpu: 2,
        ncuda: 1,
        sched: SchedPolicy::Dmda,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, Some(m.clone())).unwrap();
    let (oracle, _) = oracle_variant("matmul", 128);
    let mut hits = Vec::new();
    for i in 0..40u64 {
        let run = apps::run_once(&rt, "matmul", 128, 300 + i, None, false).unwrap();
        hits.push(run.variant == oracle);
    }
    for (w, window) in hits.chunks(10).enumerate() {
        let acc = window.iter().filter(|h| **h).count() * 100 / window.len();
        println!("   tasks {:2}-{:2}: {acc:3}% oracle ({oracle})", w * 10, w * 10 + 9);
    }
}

/// A4: pruning shortens the cold phase — tasks until first oracle pick.
fn a4_pruning(m: &Arc<Manifest>) {
    println!("\n-- A4: variant pruning vs calibration length (matmul 256) --");
    let (oracle, _) = oracle_variant("matmul", 256);
    for (label, variants) in [
        ("all 5 variants", None),
        // pruned set as computed by compar::opt at margin 1.25
        ("pruned (no omp)", Some(vec!["blas", "seq", "cuda", "cublas"])),
    ] {
        let cfg = Config {
            ncpu: 2,
            ncuda: 1,
            sched: SchedPolicy::Dmda,
            ..Config::default()
        };
        let rt = Runtime::new(cfg, Some(m.clone())).unwrap();
        // register a codelet restricted to the variant subset
        let full = apps::codelet("matmul").unwrap();
        let cl = match &variants {
            None => rt.register_codelet(full),
            Some(keep) => {
                let mut c = compar::taskrt::Codelet::new("mmul", "matmul", full.modes.clone());
                for imp in &full.impls {
                    if keep.contains(&imp.name.as_str()) {
                        c.impls.push(imp.clone());
                    }
                }
                rt.register_codelet(c)
            }
        };
        let mut first_hit = None;
        let mut streak_start = None;
        for i in 0..40u64 {
            let inst = apps::prepare(&rt, "matmul", 256, 500 + i).unwrap();
            let spec = compar::taskrt::TaskSpec::new(cl.clone(), inst.handles.clone(), 256);
            let id = rt.submit(spec).unwrap();
            rt.wait_all().unwrap();
            let r = rt
                .metrics()
                .results()
                .into_iter()
                .rev()
                .find(|r| r.task == id)
                .unwrap();
            if r.variant == oracle {
                first_hit.get_or_insert(i);
                streak_start.get_or_insert(i);
            } else {
                streak_start = None;
            }
        }
        println!(
            "   {label:18} first oracle pick at task {:?}, stable from task {:?}",
            first_hit, streak_start
        );
    }
}

fn main() {
    let Some(m) = manifest() else {
        eprintln!("ablation bench needs artifacts (run `make artifacts`)");
        std::process::exit(1);
    };
    println!("== ablation benches ==\n");
    a1_scheduler_policies(&m);
    a2_data_awareness(&m);
    a3_calibration(&m);
    a4_pruning(&m);
}
