//! Regenerates the paper's Fig. 1 panel for nw (cargo bench --bench fig1_nw).
mod common;

fn main() {
    common::run_fig1("nw");
}
