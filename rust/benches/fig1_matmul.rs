//! Regenerates the paper's Fig. 1 panel for matmul (cargo bench --bench fig1_matmul).
mod common;

fn main() {
    common::run_fig1("matmul");
}
