//! L3 hot-path overhead bench (DESIGN.md §Perf): measures the runtime's
//! per-task cost — submit -> schedule -> dispatch -> execute(noop) ->
//! complete — which must stay in the microsecond range (StarPU's own
//! overhead is ~2-10 µs/task). Also isolates scheduler push cost per
//! policy and the data-registration cost.

use std::sync::Arc;
use std::time::Duration;

use compar::runtime::Tensor;
use compar::taskrt::{AccessMode, Arch, Codelet, Config, Runtime, SchedPolicy, TaskSpec};
use compar::util::stats::{bench_budget, fmt_time};

fn per_task_overhead(sched: SchedPolicy, batch: usize) -> f64 {
    let cfg = Config {
        ncpu: 2,
        ncuda: 0,
        sched,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, None).unwrap();
    let cl = rt.register_codelet(
        Codelet::new("noop", "sort", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|_| Ok(())),
        ),
    );
    // pre-register data so the loop measures task machinery only
    let handles: Vec<_> = (0..batch)
        .map(|_| rt.register_data(Tensor::vector(vec![0.0])))
        .collect();
    let summary = bench_budget(Duration::from_millis(800), 5, || {
        for h in &handles {
            rt.submit(TaskSpec::new(cl.clone(), vec![*h], 1)).unwrap();
        }
        rt.wait_all().unwrap();
    });
    summary.median / batch as f64
}

fn registration_cost() -> f64 {
    let cfg = Config {
        ncpu: 1,
        ncuda: 0,
        sched: SchedPolicy::Eager,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, None).unwrap();
    let data = vec![0.0f32; 1024];
    let summary = bench_budget(Duration::from_millis(300), 50, || {
        let _ = rt.register_data(Tensor::vector(data.clone()));
    });
    summary.median
}

/// L2 dispatch overhead: smallest artifact through the XLA service
/// thread (channel roundtrip + PJRT execute of an 8x8 matmul) — the
/// fixed cost every artifact-backed variant pays on top of its compute.
fn xla_dispatch_overhead() -> Option<f64> {
    let m = compar::runtime::Manifest::load(&compar::runtime::manifest::default_dir()).ok()?;
    let meta = m.find("matmul", "jnp", 8)?.clone();
    let svc = compar::runtime::XlaService::spawn().ok()?;
    let h = svc.handle();
    let mut rng = compar::util::rng::Rng::new(1);
    let a = Tensor::matrix(8, 8, rng.vec_f32(64, -1.0, 1.0));
    let b = Tensor::matrix(8, 8, rng.vec_f32(64, -1.0, 1.0));
    // warm the executable cache
    let _ = h.run(&meta, vec![a.clone(), b.clone()]).ok()?;
    let s = bench_budget(Duration::from_millis(500), 20, || {
        let _ = h.run(&meta, vec![a.clone(), b.clone()]).unwrap();
    });
    Some(s.median)
}

fn main() {
    println!("== taskrt overhead (L3 hot path) ==");
    println!("target: < 10 µs/task (StarPU-class)\n");
    for sched in [
        SchedPolicy::Eager,
        SchedPolicy::Random,
        SchedPolicy::WorkStealing,
        SchedPolicy::Dmda,
        SchedPolicy::Heft,
    ] {
        let t = per_task_overhead(sched, 256);
        println!(
            "  {:8} {:>12} per task (256-task batches, noop kernel)",
            sched.name(),
            fmt_time(t)
        );
    }
    println!(
        "\n  data registration (1 KiB vector): {:>12}",
        fmt_time(registration_cost())
    );
    match xla_dispatch_overhead() {
        Some(t) => println!(
            "  XLA artifact dispatch (8x8 matmul through the service thread): {:>12}",
            fmt_time(t)
        ),
        None => println!("  XLA artifact dispatch: skipped (no artifacts)"),
    }
}
