//! Pre-compiler throughput bench: full front-end + code generation over
//! the bundled benchmark sources, plus a scaling run on a synthetic
//! many-interface program. The pre-compiler is build-time tooling, but a
//! source-to-source compiler that cannot chew megabytes of annotations
//! would be a real adoption blocker.

use std::time::Duration;

use compar::bench_harness::bundled_sources;
use compar::util::stats::{bench_budget, fmt_time};

fn synthetic_program(interfaces: usize) -> String {
    let mut src = String::from("#pragma compar include\n");
    for i in 0..interfaces {
        src.push_str(&format!(
            "#pragma compar method_declare interface(f{i}) target(cuda) name(f{i}_cuda)\n\
             #pragma compar parameter name(a) type(float*) size(N, M) access_mode(readwrite)\n\
             #pragma compar parameter name(N) type(int)\n\
             #pragma compar parameter name(M) type(int)\n\
             void f{i}_cuda(float* a, int N, int M) {{}}\n\
             #pragma compar method_declare interface(f{i}) target(openmp) name(f{i}_omp)\n\
             void f{i}_omp(float* a, int N, int M) {{}}\n"
        ));
    }
    src.push_str("#pragma compar initialize\n#pragma compar terminate\n");
    src
}

fn main() {
    println!("== COMPAR pre-compiler throughput ==\n");
    for (app, src, file) in bundled_sources() {
        let s = bench_budget(Duration::from_millis(300), 20, || {
            let _ = compar::compar::compile(&src, &file).unwrap();
        });
        println!(
            "  {app:10} {:>6} bytes  {:>12}/compile",
            src.len(),
            fmt_time(s.median)
        );
    }
    for n in [10usize, 100, 1000] {
        let src = synthetic_program(n);
        let s = bench_budget(Duration::from_millis(500), 3, || {
            let _ = compar::compar::compile(&src, "synthetic.c").unwrap();
        });
        let mb_s = src.len() as f64 / s.median / 1e6;
        println!(
            "  synthetic {n:4} interfaces ({:>8} bytes): {:>12}/compile ({mb_s:.1} MB/s)",
            src.len(),
            fmt_time(s.median)
        );
    }
}
