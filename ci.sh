#!/usr/bin/env bash
# CI gate: formatting, lints on the codebase (serve + cluster + taskrt
# included), and the tier-1 verify (build + tests). Also exercises the
# serving path end-to-end via in-process loadgen smoke runs, a real
# multi-process two-shard cluster behind `compar route`, and the bench
# record schema (validate both a fresh record and the repo baseline).
set -euo pipefail
cd "$(dirname "$0")"

echo "== rustfmt =="
cargo fmt --all -- --check

echo "== clippy (-D warnings) =="
# The two -A lints are pre-existing stylistic patterns in the seed code;
# everything else (including serve/ and cluster/) builds warning-free.
cargo clippy --release --all-targets -- \
  -D warnings \
  -A clippy::too_many_arguments \
  -A clippy::type_complexity

echo "== tier-1 verify =="
cargo build --release
cargo test -q

echo "== serve smoke (loadgen, in-process, pipelined; threads/ndjson lane) =="
cargo run --release --quiet -- loadgen \
  --clients 4 --requests 10 --app matmul --size 32 --pipeline 2 \
  --contexts alpha:2,beta:2:epsilon --ctxs alpha,beta \
  --transport threads --framing ndjson

echo "== serve smoke (epoll/binary lane: same load, multiplexed transport) =="
cargo run --release --quiet -- loadgen \
  --clients 4 --requests 10 --app matmul --size 32 --pipeline 2 \
  --contexts alpha:2,beta:2:epsilon --ctxs alpha,beta \
  --transport epoll --framing binary

echo "== many-connection soak (epoll: 192 concurrent connections) =="
# the fan-out driver exits non-zero on any connect failure or request
# error; 192 concurrent sessions on 2 workers is the regime where
# thread-per-connection thrashes and the readiness loop must not
cargo run --release --quiet -- loadgen \
  --connections 192 --requests 2 --app matmul --size 24 --ncpu 2 \
  --transport epoll --framing binary

echo "== selection-policy bench (smoke, incl. contended scenario) =="
# --smoke also runs the contended scenario and FAILS the gate if the
# contextual policy's regret exceeds greedy's under phased device
# pressure (the context-aware selection guarantee)
cargo run --release --quiet -- bench selection --smoke

echo "== cluster smoke (in-process: 2 shards behind the router) =="
cargo run --release --quiet -- loadgen --shards 2 \
  --clients 4 --requests 8 --app matmul --size 32 --pipeline 2 --ncpu 2

echo "== stream smoke (v6 sessions: calibrated SLO + overload backpressure) =="
# boots a heterogeneous server (2 cpu + 1 emulated device worker) twice:
# at the calibrated rate every chunk must land inside the SLO with zero
# drops; at overload the server must engage credit backpressure (shed
# window granularity, shrink the chunk window) before dropping anything
# — `bench stream --smoke` FAILS on either breach
cargo run --release --quiet -- bench stream --smoke

echo "== stream smoke (epoll/binary lane: loadgen stream profile) =="
# the same credit-gated stream driver over the multiplexed transport
# and binary framing: acks, credit signals, and close must all arrive
cargo run --release --quiet -- loadgen \
  --profile stream:200:16:1 --clients 2 --requests 12 --app sort \
  --transport epoll --framing binary --ncpu 2

echo "== autoscale smoke (context elasticity + shard churn) =="
# in-process: a loadgen burst on a small context must trigger a worker
# migration (asserted via the v5 autoscale_status request) and the drain
# must give the workers back; cluster: a two-shard elastic cluster must
# spawn a third shard under burst and retire it after, with zero failed
# requests throughout — `bench autoscale --smoke` FAILS on any of these
cargo run --release --quiet -- bench autoscale --smoke

echo "== dag smoke (v8 graph planning; threads/ndjson lane) =="
# one server, three graph submissions: `bench dag --smoke` FAILS unless
# the planned makespan is <= the forced-greedy makespan, at least one
# producer→consumer transfer is elided, every node reports a result,
# and the contended submit degrades to per-task greedy
cargo run --release --quiet -- bench dag --smoke \
  --transport threads --framing ndjson

echo "== dag smoke (epoll/binary lane: same gates, multiplexed transport) =="
cargo run --release --quiet -- bench dag --smoke \
  --transport epoll --framing binary

echo "== verify-model smoke (generative explorer + self-test + proofs + diff) =="
# the verified concurrency core: 10k generated op sequences over the
# pure state machine with every invariant checked per step, the
# injected-bug self-test (the harness must catch the planted
# conservation bug and shrink it to a minimal sequence), the concrete
# run of the kani proof bodies, and a short differential pass against
# the real runtime — `verify model --smoke` FAILS on any violation,
# divergence, or a self-test that no longer catches the bug
cargo run --release --quiet -- verify model --smoke

echo "== kani harness lane (proof bodies compile + run concretely) =="
# this image ships no `cargo kani`; the dev-profile check plus the
# concrete --proofs run keep the #[cfg_attr(kani, kani::proof)]
# harnesses in rust/src/model/proofs.rs from rotting. On a
# kani-equipped image, run `cargo kani` for the bounded proofs.
cargo check --quiet
cargo run --release --quiet -- verify model --proofs

# wait until a TCP port accepts connections (pure bash, no nc needed)
wait_port() {
  local port="$1"
  for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${port}") 2>/dev/null; then
      exec 3>&- 3<&- || true
      return 0
    fi
    sleep 0.1
  done
  echo "port ${port} never came up" >&2
  return 1
}

echo "== cluster smoke (multi-process: compar route + 2 compar serve) =="
# run the prebuilt binary directly (already built by the tier-1 step):
# backgrounding `cargo run` would record cargo's PID, and cargo does not
# forward signals to its child — the trap below must kill the real
# server processes so a failed step never leaves the fixed ports bound
COMPAR=target/release/compar
SHARD1=""; SHARD2=""; ROUTER=""
cleanup_cluster() {
  for pid in $ROUTER $SHARD1 $SHARD2; do
    kill "$pid" 2>/dev/null || true
  done
}
trap cleanup_cluster EXIT
"$COMPAR" serve --addr 127.0.0.1:7361 --ncpu 2 &
SHARD1=$!
"$COMPAR" serve --addr 127.0.0.1:7362 --ncpu 2 &
SHARD2=$!
wait_port 7361
wait_port 7362
"$COMPAR" route --listen 127.0.0.1:7360 \
  --shards 127.0.0.1:7361,127.0.0.1:7362 --gossip-ms 200 &
ROUTER=$!
wait_port 7360
# loadgen exits non-zero unless every request completed
"$COMPAR" loadgen --addr 127.0.0.1:7360 \
  --clients 2 --requests 6 --app matmul --size 32
# shutdown through the router drains the whole cluster; clean exits only
"$COMPAR" loadgen --addr 127.0.0.1:7360 --shutdown
wait "$ROUTER" "$SHARD1" "$SHARD2"
trap - EXIT

echo "== obs smoke (v9 metrics scrapes mid-serve, both transport lanes) =="
# two loadgen runs against one live server per transport×framing lane,
# each writing a compar-obs snapshot through a live connection before
# the server drains; `bench validate` gates every histogram's
# bucket-sum consistency plus the e2e-count/success reconcile, and
# `--prev` gates counter monotonicity between the two scrapes
for lane in "threads ndjson 7363" "epoll binary 7364"; do
  read -r OBS_TP OBS_FR OBS_PORT <<<"$lane"
  OBS1="$(mktemp)"; OBS2="$(mktemp)"; OBS_SRV=""
  cleanup_obs() { kill "$OBS_SRV" 2>/dev/null || true; rm -f "$OBS1" "$OBS2"; }
  trap cleanup_obs EXIT
  "$COMPAR" serve --addr "127.0.0.1:${OBS_PORT}" --ncpu 2 \
    --transport "$OBS_TP" --audit-cap 1024 &
  OBS_SRV=$!
  wait_port "$OBS_PORT"
  "$COMPAR" loadgen --addr "127.0.0.1:${OBS_PORT}" --clients 2 --requests 6 \
    --app matmul --size 32 --framing "$OBS_FR" --metrics-out "$OBS1"
  "$COMPAR" loadgen --addr "127.0.0.1:${OBS_PORT}" --clients 2 --requests 6 \
    --app matmul --size 32 --framing "$OBS_FR" --metrics-out "$OBS2"
  "$COMPAR" bench validate "$OBS1"
  "$COMPAR" bench validate "$OBS2" --prev "$OBS1"
  "$COMPAR" loadgen --addr "127.0.0.1:${OBS_PORT}" --shutdown
  wait "$OBS_SRV"
  cleanup_obs
  trap - EXIT
done

echo "== bench record schema (fresh record + repo baseline) =="
tmp_bench="$(mktemp)"
cargo run --release --quiet -- loadgen \
  --clients 2 --requests 4 --app matmul --size 32 --out "$tmp_bench"
cargo run --release --quiet -- bench validate "$tmp_bench"
rm -f "$tmp_bench"
cargo run --release --quiet -- bench validate BENCH_serve.json

echo "CI OK"
