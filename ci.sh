#!/usr/bin/env bash
# CI gate: formatting, lints on the codebase (serve + taskrt included),
# and the tier-1 verify (build + tests). Also exercises the serving path
# end-to-end via an in-process loadgen smoke run.
set -euo pipefail
cd "$(dirname "$0")"

echo "== rustfmt =="
cargo fmt --all -- --check

echo "== clippy (-D warnings) =="
# The two -A lints are pre-existing stylistic patterns in the seed code;
# everything else (including the serve/ subsystem) builds warning-free.
cargo clippy --release --all-targets -- \
  -D warnings \
  -A clippy::too_many_arguments \
  -A clippy::type_complexity

echo "== tier-1 verify =="
cargo build --release
cargo test -q

echo "== serve smoke (loadgen, in-process, pipelined) =="
cargo run --release --quiet -- loadgen \
  --clients 4 --requests 10 --app matmul --size 32 --pipeline 2 \
  --contexts alpha:2,beta:2:epsilon --ctxs alpha,beta

echo "== selection-policy bench (smoke) =="
cargo run --release --quiet -- bench selection --smoke

echo "CI OK"
