//! Quickstart: the paper's Listing 1.3 scenario — a `sort` and an `mmul`
//! interface, each with multiple implementation variants, left to the
//! runtime to choose from.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use compar::apps;
use compar::taskrt::{Config, Runtime, SchedPolicy};

fn main() -> Result<()> {
    // compar_init() — what `#pragma compar initialize` expands to.
    let manifest = std::sync::Arc::new(compar::runtime::Manifest::load(
        &compar::runtime::manifest::default_dir(),
    )?);
    let cfg = Config {
        ncpu: 2,
        ncuda: 1,
        sched: SchedPolicy::Dmda,
        ..Config::from_env()
    };
    let rt = Runtime::new(cfg, Some(manifest))?;
    println!(
        "COMPAR quickstart (ncpu={} ncuda={} sched={})\n",
        rt.config().ncpu,
        rt.config().ncuda,
        rt.config().sched.name()
    );

    // sort(arr, N); — Listing 1.3 line 23. Run it a few times so the
    // perf models calibrate, then watch the runtime's choice converge.
    println!("sort(arr, 4096) x 12:");
    for i in 0..12 {
        let run = apps::run_once(&rt, "sort", 4096, i, None, true)?;
        println!(
            "  run {i:2}: selected {:7} modeled {:>10} (verified, rel_err {:.1e})",
            run.variant,
            compar::util::stats::fmt_time(run.modeled),
            run.rel_err
        );
    }

    // mmul(A, B, N, M); — Listing 1.3 line 24.
    println!("\nmmul(A, B, 256, 256) x 16:");
    for i in 0..16 {
        let run = apps::run_once(&rt, "matmul", 256, 100 + i, None, true)?;
        println!(
            "  run {i:2}: selected {:7} modeled {:>10}",
            run.variant,
            compar::util::stats::fmt_time(run.modeled)
        );
    }

    println!("\nselection histogram: {:?}", rt.metrics().variant_histogram());
    println!(
        "tasks executed: {}, bytes transferred (modeled PCIe): {}",
        rt.metrics()
            .tasks_executed
            .load(std::sync::atomic::Ordering::Relaxed),
        rt.metrics()
            .bytes_transferred
            .load(std::sync::atomic::Ordering::Relaxed)
    );
    // compar_terminate() — Listing 1.3 line 25.
    rt.shutdown()?;
    Ok(())
}
