//! End-to-end driver (DESIGN.md: the full-system validation workload).
//!
//! Exercises every layer in one run:
//!  1. the **pre-compiler** compiles all bundled COMPAR-annotated
//!     benchmark sources (front-end + both code generators);
//!  2. the **runtime** comes up with the heterogeneous topology (CPU
//!     workers + the CUDA-analog device backed by real XLA/PJRT
//!     execution of the AOT Pallas/jnp artifacts);
//!  3. every benchmark app runs a calibration stream followed by a
//!     measured stream; every output is verified against the native
//!     sequential reference;
//!  4. the headline metric is reported: COMPAR's dynamic selection vs
//!     the best and worst static variant choice (the paper's claim is
//!     that dynamic selection tracks the best variant without the
//!     developer hard-coding it).
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::sync::Arc;

use anyhow::{bail, Result};
use compar::apps;
use compar::bench_harness::{bundled_sources, fig1};
use compar::runtime::Manifest;
use compar::taskrt::device::Arch;
use compar::taskrt::{Config, Runtime, SchedPolicy};
use compar::util::stats::fmt_time;

fn main() -> Result<()> {
    println!("========== COMPAR end-to-end validation ==========\n");

    // ---- phase 1: pre-compiler over all bundled sources -------------
    println!("[1/3] pre-compiling {} annotated sources", bundled_sources().len());
    let mut total_directives = 0;
    let mut total_glue = 0;
    for (app, src, file) in bundled_sources() {
        let out = compar::compar::compile(&src, &file)?;
        let directives = compar::bench_harness::table1f::compar_loc(&src);
        let glue: usize = out
            .c_units
            .iter()
            .map(|(_, c)| c.lines().filter(|l| !l.trim().is_empty()).count())
            .sum();
        total_directives += directives;
        total_glue += glue;
        println!(
            "  {app:10} {} interface(s), {directives:3} directive lines -> {glue:3} glue lines",
            out.program.interfaces.len()
        );
    }
    println!(
        "  total: {total_directives} developer lines replace {total_glue} lines of StarPU glue\n"
    );

    // ---- phase 2: heterogeneous runtime --------------------------------
    let manifest = Arc::new(Manifest::load(&compar::runtime::manifest::default_dir())?);
    println!(
        "[2/3] runtime up: {} artifacts, topology = 4 cpu + 1 cuda, sched = dmda",
        manifest.artifacts.len()
    );
    let cfg = Config {
        ncpu: 4,
        ncuda: 1,
        sched: SchedPolicy::Dmda,
        ..Config::from_env()
    };
    let rt = Runtime::new(cfg, Some(manifest.clone()))?;

    // ---- phase 3: all apps, calibrate -> run -> verify ---------------
    println!("[3/3] running all benchmark apps (verify every output)\n");
    let workloads: &[(&str, usize)] = &[
        ("hotspot", 128),
        ("hotspot3d", 64),
        ("lud", 128),
        ("nw", 127),
        ("matmul", 128),
        ("sort", 4096),
    ];
    let mut summary = Vec::new();
    for &(app, size) in workloads {
        let nvariants = apps::codelet(app)?.impls.len();
        let calib = (compar::taskrt::perfmodel::MIN_SAMPLES + 1) * nvariants;
        for i in 0..calib {
            apps::run_once(&rt, app, size, 5000 + i as u64, None, true)?;
        }
        rt.drain_results();
        // measured stream: 6 runs of dynamic selection
        let mut modeled = Vec::new();
        let mut selected = String::new();
        for i in 0..6 {
            let run = apps::run_once(&rt, app, size, 6000 + i, None, true)?;
            modeled.push(run.modeled);
            selected = run.variant;
        }
        let dyn_t = modeled.iter().copied().sum::<f64>() / modeled.len() as f64;
        // static baselines from the converged model
        let times: Vec<(f64, &str)> = apps::paper_variants(app)
            .iter()
            .map(|v| {
                let arch = Arch::parse(v).unwrap_or(Arch::Cpu);
                (fig1::variant_time(app, v, arch, size), *v)
            })
            .collect();
        let best = times.iter().cloned().fold((f64::MAX, ""), |a, b| if b.0 < a.0 { b } else { a });
        let worst = times.iter().cloned().fold((0.0, ""), |a, b| if b.0 > a.0 { b } else { a });
        let overhead = (dyn_t / best.0 - 1.0) * 100.0;
        println!(
            "  {app:10} n={size:5}  COMPAR={:>10} ({selected:7})  best-static={:>10} ({})  worst-static={:>10} ({})  overhead vs best: {overhead:+.1}%",
            fmt_time(dyn_t), fmt_time(best.0), best.1, fmt_time(worst.0), worst.1
        );
        summary.push((app, dyn_t, best.0, worst.0, overhead));
    }

    // ---- headline ----------------------------------------------------
    let avg_overhead: f64 =
        summary.iter().map(|(_, _, _, _, o)| *o).sum::<f64>() / summary.len() as f64;
    let avg_saving: f64 = summary
        .iter()
        .map(|(_, d, _, w, _)| (w / d).max(1.0))
        .sum::<f64>()
        / summary.len() as f64;
    println!(
        "\nheadline: dynamic selection averages {avg_overhead:+.1}% vs the best static \
         variant\n          and {avg_saving:.1}x faster than the worst static choice \
         (the cost of hard-coding wrongly)."
    );
    println!(
        "\ntasks executed: {}, all outputs verified against native references.",
        rt.metrics()
            .tasks_executed
            .load(std::sync::atomic::Ordering::Relaxed)
    );
    if avg_overhead > 25.0 {
        bail!("selection overhead unexpectedly high ({avg_overhead:.1}%)");
    }
    rt.shutdown()?;
    println!("========== end-to-end validation PASSED ==========");
    Ok(())
}
