//! Task-graph example: blocked matrix multiplication as a DAG of tasks
//! over block handles — the pattern StarPU was built for, and the
//! natural extension of the paper's single-task interfaces. Shows:
//! implicit data dependencies (block accumulation chains), priorities,
//! heterogeneous placement of independent block products, and the
//! chrome://tracing export.
//!
//! C[i][j] = sum_k A[i][k] @ B[k][j], each product its own task; the
//! accumulation into C[i][j] serializes through the handle's RW chain.
//!
//! ```bash
//! make artifacts && cargo run --release --example task_graph
//! ```

use std::sync::Arc;

use anyhow::Result;
use compar::runtime::{Manifest, Tensor};
use compar::taskrt::{
    AccessMode, Arch, Codelet, Config, Runtime, SchedPolicy, TaskSpec,
};
use compar::util::rng::Rng;

const B: usize = 128; // block size (an AOT matmul artifact exists for it)
const NB: usize = 3; // blocks per dimension -> 27 product tasks

fn main() -> Result<()> {
    let manifest = Arc::new(Manifest::load(&compar::runtime::manifest::default_dir())?);
    let rt = Runtime::new(
        Config {
            ncpu: 4,
            ncuda: 1,
            sched: SchedPolicy::Dmda,
            ..Config::from_env()
        },
        Some(manifest),
    )?;

    // one codelet: C += A@B on B x B blocks. The artifact computes A@B;
    // the native variants accumulate directly.
    let gemm_acc = rt.register_codelet(
        Codelet::new(
            "gemm_acc",
            "matmul",
            vec![AccessMode::Read, AccessMode::Read, AccessMode::ReadWrite],
        )
        .with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|bufs| {
                let a = bufs.read(0).data().to_vec();
                let b = bufs.read(1).data().to_vec();
                let mut c = bufs.write(2);
                let n = bufs.size;
                let mut tmp = vec![0.0f32; n * n];
                compar::apps::matmul::matmul_omp(&a, &b, &mut tmp, n);
                for (ci, ti) in c.data_mut().iter_mut().zip(&tmp) {
                    *ci += *ti;
                }
                Ok(())
            }),
        ),
    );

    // register block handles
    let mut rng = Rng::new(77);
    let blocks = |rng: &mut Rng| -> Vec<Vec<compar::taskrt::HandleId>> {
        (0..NB)
            .map(|_| {
                (0..NB)
                    .map(|_| {
                        rt.register_data(Tensor::matrix(B, B, rng.vec_f32(B * B, -1.0, 1.0)))
                    })
                    .collect()
            })
            .collect()
    };
    let a = blocks(&mut rng);
    let b = blocks(&mut rng);
    let c: Vec<Vec<_>> = (0..NB)
        .map(|_| {
            (0..NB)
                .map(|_| rt.register_data(Tensor::zeros(vec![B, B])))
                .collect()
        })
        .collect();

    // submit the DAG: 27 products; accumulations into the same C block
    // serialize automatically via the RW chain on that handle.
    println!("submitting {} block-product tasks ({NB}x{NB} blocks of {B}x{B})", NB * NB * NB);
    for i in 0..NB {
        for j in 0..NB {
            for k in 0..NB {
                // earlier k gets higher priority: frees the diagonal first
                let spec = TaskSpec::new(
                    gemm_acc.clone(),
                    vec![a[i][k], b[k][j], c[i][j]],
                    B,
                )
                .with_priority((NB - k) as i32);
                rt.submit(spec)?;
            }
        }
    }
    rt.wait_all()?;

    // verify against a flat single-task reference
    let mut ok = true;
    for i in 0..NB {
        for j in 0..NB {
            let mut want = vec![0.0f32; B * B];
            for k in 0..NB {
                let ab = rt.snapshot(a[i][k])?;
                let bb = rt.snapshot(b[k][j])?;
                let mut tmp = vec![0.0f32; B * B];
                compar::apps::matmul::matmul_seq(ab.data(), bb.data(), &mut tmp, B);
                for (w, t) in want.iter_mut().zip(&tmp) {
                    *w += *t;
                }
            }
            let got = rt.snapshot(c[i][j])?;
            let err = got.rel_l2_error(&Tensor::matrix(B, B, want));
            if err > 1e-4 {
                println!("block ({i},{j}): rel err {err}");
                ok = false;
            }
        }
    }
    println!(
        "verification: {}",
        if ok { "all blocks correct" } else { "FAILED" }
    );

    let hist = rt.metrics().variant_histogram();
    println!("variant histogram: {hist:?}");

    let trace_path = std::path::Path::new("target/task_graph_trace.json");
    rt.export_chrome_trace(trace_path)?;
    println!(
        "execution trace written to {} (open in chrome://tracing or perfetto.dev)",
        trace_path.display()
    );
    if !ok {
        anyhow::bail!("verification failed");
    }
    rt.shutdown()?;
    Ok(())
}
