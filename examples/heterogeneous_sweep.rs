//! Heterogeneous sweep: a miniature Fig. 1 for one app — CPU-only vs
//! GPU-only vs COMPAR dynamic selection across input sizes, on the real
//! runtime where artifacts exist and through the calibrated device model
//! beyond.
//!
//! ```bash
//! make artifacts && cargo run --release --example heterogeneous_sweep -- [--app matmul] [--quick]
//! ```

use std::sync::Arc;

use anyhow::Result;
use compar::bench_harness::fig1;
use compar::runtime::Manifest;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let app = args
        .iter()
        .position(|a| a == "--app")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("matmul");

    let manifest = Manifest::load(&compar::runtime::manifest::default_dir())
        .ok()
        .map(Arc::new);
    if manifest.is_none() {
        eprintln!("note: no artifacts found; all rows will be model-derived");
    }
    let (reps, max_measured) = if quick { (1, 64) } else { (3, 256) };
    let points = fig1::series(app, manifest.as_ref(), reps, max_measured)?;
    println!("{}", fig1::render(app, &points));
    if app == "matmul" {
        println!("{}", fig1::matmul_variant_table());
    }
    Ok(())
}
