//! Pre-compiler demo: runs the full COMPAR front-end + code generators
//! on the bundled annotated source of the paper's Listing 1.3 (sort) and
//! prints every artifact: the StarPU C glue (paper Listing 1.4), the
//! compar.h header, the transformed application source and the Rust glue
//! for our taskrt back-end.
//!
//! ```bash
//! cargo run --release --example precompiler_demo
//! ```

use anyhow::Result;

const SOURCE: &str = include_str!("compar_src/sort.compar.c");

fn main() -> Result<()> {
    println!("=== input: sort.compar.c ({} lines) ===", SOURCE.lines().count());
    println!("{SOURCE}");

    let out = compar::compar::compile(SOURCE, "sort.compar.c")?;

    println!("=== generated StarPU glue (paper Listing 1.4) ===");
    for (name, contents) in &out.c_units {
        println!("--- {name} ---\n{contents}");
    }

    println!("=== generated compar.h ===\n{}", out.header);
    println!("=== transformed application source ===\n{}", out.transformed);
    println!("=== Rust glue (taskrt back-end) ===\n{}", out.rust_glue);

    // show the semantic analyzer too: a deliberately broken program
    let broken = "\
#pragma compar method_declare interface(f) target(fpga) name(f1)
#pragma compar parameter name(x) type(quaternion)
#pragma compar parameter name(x) type(int)
";
    println!("=== diagnostics demo (broken input) ===");
    match compar::compar::analyze(broken, "broken.compar.c") {
        Ok(_) => println!("unexpectedly clean"),
        Err(e) => println!("{e:#}"),
    }
    Ok(())
}
